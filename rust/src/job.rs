//! [`CompressionJob`]: the single user-facing entry point for TTD
//! compression + SoC costing.
//!
//! Replaces the positional-argument sprawl (`delta`, rank caps,
//! thread counts and `&mut S` sinks threaded through a dozen
//! signatures) with one builder:
//!
//! ```
//! use tt_edge::sim::SocConfig;
//! use tt_edge::ttd::Tensor;
//! use tt_edge::util::Rng;
//! use tt_edge::CompressionJob;
//!
//! let mut rng = Rng::new(7);
//! let w = Tensor::from_vec(&[4, 4, 4], rng.normal_vec(64));
//! let out = CompressionJob::new(&w)
//!     .eps(0.1)
//!     .rank_cap(8)
//!     .soc(SocConfig::tt_edge())
//!     .run()
//!     .unwrap();
//! assert_eq!(out.outcome.decomps.len(), 1);
//! assert_eq!(out.reports.len(), 1);
//! ```
//!
//! The default path streams: every hardware op folds into a
//! [`CostSink`] as it is emitted, so costing a model allocates no
//! `Vec<HwOp>` proportional to the trace — summaries merge
//! deterministically in layer order at any `parallel(n)` width and
//! are bit-identical to a recorded-trace replay. Attaching an
//! observer with [`CompressionJob::sink`] opts into per-layer trace
//! buffering (the observer sees the exact serial-order op stream) —
//! that is the only path that stores ops.

use std::cell::Cell;

use crate::cache::{CacheKey, Claim, Fingerprint, ProgramCache};
use crate::fault::JobError;
use crate::model::resnet32::ConvLayer;
use crate::model::transformer::TransformerSpec;
use crate::pipeline::{self, CancelToken};
use crate::sim::config::SocConfig;
use crate::sim::cost::CostSink;
use crate::sim::report::SimReport;
use crate::sim::workload::{
    aggregate_outcome_conv, aggregate_outcome_model, synthetic_model, CompressionOutcome,
};
use crate::trace::{OpProgram, RecordingSink, Tee, TraceSink, VecSink};
use crate::ttd::svd::bidiag;
use crate::ttd::tensor::{set_gemm_kernel, GemmKernel};
use crate::ttd::ttd::TtSpec;
use crate::ttd::{decompose, relative_error, Tensor};

thread_local! {
    /// Numerics passes started by [`CompressionJob`] on this thread
    /// (replay jobs never count). Thread-local on purpose: a pass is
    /// attributed to the thread that called `run`/`program` — worker
    /// threads the pipeline fans layers out to are part of that one
    /// pass — so concurrent test threads cannot see each other's
    /// passes.
    static NUMERICS_PASSES: Cell<u64> = const { Cell::new(0) };
}

/// Total numerics passes [`CompressionJob`] has started on the calling
/// thread. The DSE driver asserts record-once / replay-many against
/// this: `explore` must move it by exactly 1 regardless of strategy or
/// generation count.
pub fn numerics_pass_count() -> u64 {
    NUMERICS_PASSES.with(|c| c.get())
}

fn record_numerics_pass() {
    NUMERICS_PASSES.with(|c| c.set(c.get() + 1));
}

enum Input<'a> {
    /// One bare tensor: a single Algorithm-1 run.
    Tensor(&'a Tensor),
    /// A model: owned `(layer, tensor)` pairs.
    Layers(&'a [(ConvLayer, Tensor)]),
    /// A model whose layers and tensors live in separate collections
    /// (the coordinator's per-node locals) — no weight cloning.
    Refs(Vec<(&'a ConvLayer, &'a Tensor)>),
    /// The synthetic-trained ResNet-32 workload (Table I/III).
    Synthetic { seed: u64, ratio: f64, noise: f32 },
    /// A synthetic-trained transformer decoder stack, or its
    /// activation-map variant (ISSUE 9). Weights are materialized
    /// lazily like [`Input::Synthetic`], so cache hits and key
    /// computation never generate them.
    Transformer { spec: TransformerSpec, activations: bool, seed: u64 },
    /// A recorded op program: no numerics at all, just costing.
    Replay(&'a JobProgram),
}

impl Input<'_> {
    /// The workload's own whole-model inventory when it is not the
    /// ResNet-32 one (see `workload::aggregate_outcome_model`).
    fn model_dense_override(&self) -> Option<usize> {
        match self {
            Input::Transformer { spec, activations, .. } => Some(if *activations {
                spec.activation_count()
            } else {
                spec.param_count()
            }),
            _ => None,
        }
    }
}

/// The record-once artifact of a job: the RLE-compacted hardware-op
/// stream (one segment per layer, serial layer order) plus the
/// config-independent compression summary. Produced by
/// [`CompressionJob::program`]; replayed against arbitrarily many SoC
/// banks by [`CompressionJob::replay`] without touching the numerics
/// — costing a program is bit-identical (cycles, energy, per-phase
/// banks) to live-costing the run that recorded it.
#[derive(Clone, Debug)]
pub struct JobProgram {
    /// The compacted op stream (order-preserving; see [`OpProgram`]).
    pub ops: OpProgram,
    model_dense_params: usize,
    conv_dense_params: usize,
    conv_tt_params: usize,
    final_params: usize,
    compression_ratio: f64,
    max_rel_err: f32,
}

impl JobProgram {
    fn from_outcome(ops: OpProgram, o: &CompressionOutcome) -> Self {
        JobProgram {
            ops,
            model_dense_params: o.model_dense_params,
            conv_dense_params: o.conv_dense_params,
            conv_tt_params: o.conv_tt_params,
            final_params: o.final_params,
            compression_ratio: o.compression_ratio,
            max_rel_err: o.max_rel_err,
        }
    }

    /// The recorded compression summary. Decompositions are not stored
    /// in a program (replay only needs costing), so `decomps` is empty
    /// — every scalar field matches the recording run exactly.
    pub fn outcome(&self) -> CompressionOutcome {
        CompressionOutcome {
            decomps: Vec::new(),
            model_dense_params: self.model_dense_params,
            conv_dense_params: self.conv_dense_params,
            conv_tt_params: self.conv_tt_params,
            final_params: self.final_params,
            compression_ratio: self.compression_ratio,
            max_rel_err: self.max_rel_err,
        }
    }
}

/// Builder for one compression job; see the [module docs](self).
pub struct CompressionJob<'a> {
    input: Input<'a>,
    spec: TtSpec,
    threads: usize,
    kernel: Option<GemmKernel>,
    hbd_threads: Option<usize>,
    configs: Vec<SocConfig>,
    cancel: Option<&'a CancelToken>,
    observer: Option<&'a mut dyn TraceSink>,
    cache: Option<&'a ProgramCache>,
}

/// What a [`CompressionJob`] produced.
#[derive(Debug)]
pub struct JobOutput {
    /// Decompositions + parameter accounting. For single-tensor jobs
    /// the "model" is just that tensor (`model_dense_params ==
    /// numel`); for model jobs this is the whole-ResNet-32 accounting
    /// every legacy path reported.
    pub outcome: CompressionOutcome,
    /// One simulation report per [`CompressionJob::soc`] config, in
    /// the order they were added (empty when none were).
    pub reports: Vec<SimReport>,
}

impl JobOutput {
    /// The first (for single-tensor jobs: the only) decomposition.
    /// Panics on replay outputs — programs carry the compression
    /// summary but no decompositions (see [`JobProgram::outcome`]).
    pub fn decomp(&self) -> &crate::ttd::TtDecomp {
        self.outcome
            .decomps
            .first()
            .expect("replay JobOutputs carry no decompositions")
    }

    /// The first configured SoC's report; panics if no `.soc(..)` was
    /// configured.
    pub fn report(&self) -> &SimReport {
        self.reports.first().expect("CompressionJob had no .soc(..) config")
    }
}

impl<'a> CompressionJob<'a> {
    fn with_input(input: Input<'a>) -> Self {
        CompressionJob {
            input,
            spec: TtSpec::default(),
            threads: 1,
            kernel: None,
            hbd_threads: None,
            configs: Vec::new(),
            cancel: None,
            observer: None,
            cache: None,
        }
    }

    /// Compress one tensor (a single Algorithm-1 run; `parallel` does
    /// not apply).
    pub fn new(tensor: &'a Tensor) -> Self {
        Self::with_input(Input::Tensor(tensor))
    }

    /// Compress a model given as owned `(layer, tensor)` pairs.
    ///
    /// Parameter accounting in [`JobOutput::outcome`] is whole-
    /// ResNet-32 (the repo's model inventory), matching every legacy
    /// path — see `workload::aggregate_outcome_conv`.
    pub fn model(layers: &'a [(ConvLayer, Tensor)]) -> Self {
        Self::with_input(Input::Layers(layers))
    }

    /// Compress a model whose layers and tensors live in separate
    /// collections — borrows everything, clones nothing.
    pub fn layer_refs(jobs: Vec<(&'a ConvLayer, &'a Tensor)>) -> Self {
        Self::with_input(Input::Refs(jobs))
    }

    /// Compress the synthetic-trained ResNet-32 (the Table-I/III
    /// workload at the repo's calibrated ratio/noise).
    pub fn synthetic(seed: u64) -> Self {
        Self::with_input(Input::Synthetic { seed, ratio: 3.55, noise: 0.035 })
    }

    /// Compress a synthetic-trained transformer decoder stack
    /// (ISSUE 9): the QKV/O projections plus FFN up/down pair per
    /// block, generated at [`TransformerSpec`]'s planted weight
    /// ratio. Outcome accounting is whole-model against
    /// [`TransformerSpec::param_count`].
    pub fn transformer(spec: TransformerSpec, seed: u64) -> Self {
        Self::with_input(Input::Transformer { spec, activations: false, seed })
    }

    /// Compress the activation-map variant of a transformer workload:
    /// one `seq_len x d_model` activation stack per block, against
    /// [`TransformerSpec::activation_count`].
    pub fn transformer_activations(spec: TransformerSpec, seed: u64) -> Self {
        Self::with_input(Input::Transformer { spec, activations: true, seed })
    }

    /// Replay a recorded [`JobProgram`] instead of running numerics:
    /// [`run`] folds the program into the `.soc(..)` bank (bit-
    /// identical to the live-costed recording run) and reuses the
    /// recorded compression summary ([`JobProgram::outcome`] — no
    /// decompositions). `.eps`/`.rank_cap` have no effect on a replay;
    /// `.parallel(n)` selects the width of the per-layer program fold
    /// (`CostSink::fold_program_parallel` — bit-identical to the
    /// serial fold at any width); `.sink(..)` observers still receive
    /// the exact recorded op stream.
    ///
    /// [`run`]: CompressionJob::run
    pub fn replay(program: &'a JobProgram) -> Self {
        Self::with_input(Input::Replay(program))
    }

    /// Prescribed relative accuracy (Oseledets `eps`; the per-split
    /// truncation threshold `delta` derives from it).
    pub fn eps(mut self, eps: f32) -> Self {
        self.spec.eps = eps;
        self
    }

    /// Alias for [`CompressionJob::eps`] under the paper's
    /// delta-truncation name.
    pub fn delta(self, eps: f32) -> Self {
        self.eps(eps)
    }

    /// Cap every TT bond rank (see [`TtSpec::rank_cap`]).
    pub fn rank_cap(mut self, cap: usize) -> Self {
        self.spec = self.spec.rank_cap(cap);
        self
    }

    /// Per-bond rank caps (see [`TtSpec::rank_caps`]).
    pub fn rank_caps(mut self, caps: &[usize]) -> Self {
        self.spec = self.spec.rank_caps(caps);
        self
    }

    /// Replace the whole numeric spec at once.
    pub fn spec(mut self, spec: TtSpec) -> Self {
        self.spec = spec;
        self
    }

    /// Host worker threads for the layer fan-out (work-stealing; the
    /// simulated SoC cost is invariant to this). On a replay job the
    /// same width drives the parallel program fold instead.
    pub fn parallel(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Select the GEMM microkernel for this process
    /// ([`GemmKernel::Vectorized`] is the default; `Reference` is the
    /// pinned scalar loop). The two kernels are bit-identical by
    /// construction, so this is a raw-speed knob only — traces, ranks
    /// and reports do not change. Note the selection is **process-
    /// wide** (it sets the same global that the `TTEDGE_KERNEL` env
    /// var seeds), not scoped to this job.
    pub fn kernel(mut self, kernel: GemmKernel) -> Self {
        self.kernel = Some(kernel);
        self
    }

    /// Worker threads for the row-band GEMM passes **inside** each
    /// bidiagonalization (compact-WY accumulation). Composes with
    /// [`CompressionJob::parallel`]: layer fan-out times in-layer
    /// bands. Bit-identical to serial at any width — row bands leave
    /// every k-accumulation chain intact. Process-wide, like
    /// [`CompressionJob::kernel`] (seeded by `TTEDGE_HBD_THREADS`).
    pub fn hbd_threads(mut self, threads: usize) -> Self {
        self.hbd_threads = Some(threads);
        self
    }

    /// Add one SoC configuration to cost the op stream under
    /// (streaming, all configs in a single pass). Chain to compare
    /// microarchitectures.
    pub fn soc(mut self, config: SocConfig) -> Self {
        self.configs.push(config);
        self
    }

    /// Add several SoC configurations at once.
    pub fn socs(mut self, configs: &[SocConfig]) -> Self {
        self.configs.extend(configs.iter().cloned());
        self
    }

    /// Cooperative cancellation: a tripped token makes [`try_run`]
    /// return [`JobError::Cancelled`] (and [`run`] `None`) — never a
    /// partial result.
    ///
    /// [`run`]: CompressionJob::run
    /// [`try_run`]: CompressionJob::try_run
    pub fn cancel(mut self, token: &'a CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Attach an observer sink that receives the full op stream in
    /// serial layer order (on top of — not instead of — the streaming
    /// cost fold). Opts this job into per-layer trace buffering.
    pub fn sink(mut self, observer: &'a mut dyn TraceSink) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Serve this job through a keyed program cache. [`run`] first
    /// claims [`CompressionJob::cache_key`] in `cache`: a hit replays
    /// the resident [`JobProgram`] (zero numerics, reports bit-
    /// identical to a fresh run by the PR-5 replay contract); a miss
    /// records the numerics **once** via [`CompressionJob::program`]
    /// and populates the cache. Misses are single-flight — concurrent
    /// callers of the same key coalesce onto one recording — so R
    /// cached runs over K unique keys cost exactly K numerics passes.
    /// Has no effect on an explicit [`CompressionJob::replay`] job
    /// (that input already *is* a program).
    ///
    /// [`run`]: CompressionJob::run
    pub fn cached(mut self, cache: &'a ProgramCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// The cache identity of this job: an order-sensitive fingerprint
    /// of the workload (generator parameters for synthetic models —
    /// the generator is deterministic, so they pin the weights without
    /// materializing them; exact TT dims + weight bits for explicit
    /// tensors) combined with the **full** numeric spec, `eps` and the
    /// effective per-bond rank caps both. Two jobs share a key iff
    /// their numerics are guaranteed identical. Panics on a
    /// [`CompressionJob::replay`] job — a program has no workload to
    /// fingerprint.
    pub fn cache_key(&self) -> CacheKey {
        let mut fp = Fingerprint::new();
        let bonds = match &self.input {
            Input::Replay(_) => panic!("CompressionJob::cache_key: replay jobs have no cache identity"),
            Input::Tensor(w) => {
                fp.push_str("tensor");
                fp.push_usize(w.shape.len());
                for &d in &w.shape {
                    fp.push_usize(d);
                }
                fp.push_f32s(&w.data);
                w.shape.len().saturating_sub(1)
            }
            // Layers and Refs digest identically on purpose: same
            // content, same numerics, same key.
            Input::Layers(layers) => {
                fp.push_str("model");
                fp.push_usize(layers.len());
                for (l, w) in layers.iter() {
                    fingerprint_layer(&mut fp, l, w);
                }
                2
            }
            Input::Refs(jobs) => {
                fp.push_str("model");
                fp.push_usize(jobs.len());
                for &(l, w) in jobs {
                    fingerprint_layer(&mut fp, l, w);
                }
                2
            }
            Input::Synthetic { seed, ratio, noise } => {
                fp.push_str("synthetic-resnet32");
                fp.push_u64(*seed);
                fp.push_u64(ratio.to_bits());
                fp.push_u64(u64::from(noise.to_bits()));
                2
            }
            // The generator is deterministic in (spec, seed) — its
            // ratio/noise are crate constants — so the spec fields pin
            // the weights without materializing them.
            Input::Transformer { spec, activations, seed } => {
                fp.push_str(if *activations { "transformer-acts" } else { "transformer-weights" });
                fp.push_str(spec.name);
                fp.push_usize(spec.d_model);
                fp.push_usize(spec.d_ff);
                fp.push_usize(spec.layers);
                fp.push_usize(spec.seq_len);
                fp.push_u64(*seed);
                2
            }
        };
        CacheKey::new(fp.finish(), &self.spec, bonds)
    }

    /// Apply the process-wide tuning knobs (`.kernel(..)` /
    /// `.hbd_threads(..)`) before any numerics or fold runs. Safe to
    /// call more than once per job; every mode is bit-identical, so a
    /// concurrent job flipping the globals cannot change results.
    fn apply_tuning(&self) {
        if let Some(kernel) = self.kernel {
            set_gemm_kernel(kernel);
        }
        if let Some(threads) = self.hbd_threads {
            bidiag::set_panel_threads(threads);
        }
    }

    /// The cache-served run path (`.cached(..)` was configured and the
    /// input is not already a replay).
    fn try_run_cached(mut self) -> Result<JobOutput, JobError> {
        let cache = self.cache.take().expect("try_run_cached requires .cached(..)");
        let key = self.cache_key();
        match cache.claim(&key) {
            Claim::Hit(program) => {
                let CompressionJob { threads, configs, cancel, observer, .. } = self;
                let default_token = CancelToken::default();
                let cancel = cancel.unwrap_or(&default_token);
                if cancel.is_cancelled() {
                    return Err(JobError::Cancelled);
                }
                let reports = cost_program(&program, &configs, observer, threads);
                Ok(JobOutput { outcome: program.outcome(), reports })
            }
            Claim::Miss(guard) => match self.try_program() {
                Ok((out, program)) => {
                    guard.fulfill(program);
                    Ok(out)
                }
                // Cancelled or rejected mid-recording: the guard's
                // drop releases the pending slot so a waiter can take
                // over the key.
                Err(e) => Err(e),
            },
        }
    }

    /// Run the job, swallowing the failure reason: `None` when the
    /// cancel token tripped or the input was rejected. Thin wrapper
    /// over [`CompressionJob::try_run`], which reports the structured
    /// [`JobError`] instead.
    pub fn run(self) -> Option<JobOutput> {
        self.try_run().ok()
    }

    /// Run the job, reporting failures as a structured [`JobError`]:
    /// [`JobError::Cancelled`] when the token tripped,
    /// [`JobError::NonFiniteInput`] when a weight tensor carries a
    /// NaN/Inf (every materialized input is screened at this boundary
    /// before any numerics run). A hard-stalled SVD escapes as a panic
    /// carrying [`JobError::SvdNonConvergence`] rather than a `Result`
    /// — it is raised mid-recording on purpose so supervisors exercise
    /// the cache's pending-release path; [`crate::fault::supervise`]
    /// converts that panic back into this error taxonomy.
    pub fn try_run(self) -> Result<JobOutput, JobError> {
        self.apply_tuning();
        if self.cache.is_some() && !matches!(self.input, Input::Replay(_)) {
            return self.try_run_cached();
        }
        let CompressionJob { input, spec, threads, configs, cancel, observer, .. } = self;
        let default_token = CancelToken::default();
        let cancel = cancel.unwrap_or(&default_token);

        // Replay: no numerics at all (and no numerics-pass count) —
        // fold the recorded program into the cost bank and reuse the
        // recorded compression summary.
        if let Input::Replay(p) = &input {
            if cancel.is_cancelled() {
                return Err(JobError::Cancelled);
            }
            let reports = cost_program(p, &configs, observer, threads);
            return Ok(JobOutput { outcome: p.outcome(), reports });
        }

        // Single tensor: one Algorithm-1 run, streamed straight into
        // the cost sink (and the observer, when attached).
        if let Input::Tensor(w) = &input {
            if cancel.is_cancelled() {
                return Err(JobError::Cancelled);
            }
            screen_tensor(w, 0)?;
            record_numerics_pass();
            let mut cost = CostSink::new(&configs);
            let d = match observer {
                Some(obs) => {
                    let mut tee = Tee::new(&mut cost, obs);
                    decompose(w, &spec, &mut tee)
                }
                None => decompose(w, &spec, &mut cost),
            };
            // Same contract as the model path: a token tripped while
            // the numerics ran means no result escapes.
            if cancel.is_cancelled() {
                return Err(JobError::Cancelled);
            }
            let outcome = single_tensor_outcome(w, d);
            return Ok(JobOutput { outcome, reports: cost.reports() });
        }

        // Model inputs: resolve to borrowed (layer, tensor) jobs.
        let model_dense = input.model_dense_override();
        let mut owned = None;
        let jobs = resolve_model_input(input, &mut owned);
        screen_jobs(&jobs)?;
        let conv_dense: usize = jobs.iter().map(|(l, _)| l.numel()).sum();
        if cancel.is_cancelled() {
            return Err(JobError::Cancelled);
        }
        record_numerics_pass();

        if let Some(obs) = observer {
            // Observer path: record per-layer traces, then stream them
            // in layer order through a tee of (cost fold, observer) —
            // the observer sees exactly the serial trace.
            let results =
                pipeline::compress_layers_sinked(&jobs, &spec, threads, cancel, VecSink::default)
                    .ok_or(JobError::Cancelled)?;
            let mut cost = CostSink::new(&configs);
            {
                let mut tee = Tee::new(&mut cost, obs);
                for r in &results {
                    r.sink.replay(&mut tee);
                }
            }
            let max_rel = results.iter().map(|r| r.rel_err).fold(0.0f32, f32::max);
            let decomps = results.into_iter().map(|r| r.decomp).collect();
            let outcome = aggregate(model_dense, conv_dense, decomps, max_rel);
            return Ok(JobOutput { outcome, reports: cost.reports() });
        }

        // Default: the streaming path — per-layer cost folds merged in
        // layer order, no per-op storage anywhere.
        let batch = pipeline::compress_layers_costed(&jobs, &spec, threads, cancel, &configs)
            .ok_or(JobError::Cancelled)?;
        let reports = batch.reports();
        let outcome = aggregate(model_dense, conv_dense, batch.decomps, batch.max_rel_err);
        Ok(JobOutput { outcome, reports })
    }

    /// Run the job's numerics **once**, recording the op stream as an
    /// RLE [`JobProgram`] alongside the normal output. The program
    /// replays against any config bank via [`CompressionJob::replay`];
    /// this call's own reports are produced by folding the freshly
    /// recorded program (not by live costing), so recording and every
    /// later replay are bit-identical by construction. `.sink(..)`
    /// observers still receive the exact serial-order stream.
    ///
    /// Returns `None` iff the job failed (cancelled or rejected —
    /// thin wrapper over [`CompressionJob::try_program`]). Panics on a
    /// [`CompressionJob::replay`] job — there are no numerics to
    /// record.
    pub fn program(self) -> Option<(JobOutput, JobProgram)> {
        self.try_program().ok()
    }

    /// [`CompressionJob::program`] with the structured failure
    /// taxonomy of [`CompressionJob::try_run`]: every materialized
    /// input is NaN/Inf-screened before the recording starts, and a
    /// tripped token maps to [`JobError::Cancelled`].
    pub fn try_program(self) -> Result<(JobOutput, JobProgram), JobError> {
        self.apply_tuning();
        let CompressionJob { input, spec, threads, configs, cancel, observer, .. } = self;
        let default_token = CancelToken::default();
        let cancel = cancel.unwrap_or(&default_token);
        assert!(
            !matches!(input, Input::Replay(_)),
            "CompressionJob::program: a replay job has no numerics to record"
        );

        // Single tensor: record one Algorithm-1 run.
        if let Input::Tensor(w) = &input {
            if cancel.is_cancelled() {
                return Err(JobError::Cancelled);
            }
            screen_tensor(w, 0)?;
            record_numerics_pass();
            let mut rec = RecordingSink::default();
            let d = decompose(w, &spec, &mut rec);
            if cancel.is_cancelled() {
                return Err(JobError::Cancelled);
            }
            let mut ops = OpProgram::default();
            ops.push_layer(rec);
            let outcome = single_tensor_outcome(w, d);
            let program = JobProgram::from_outcome(ops, &outcome);
            let reports = cost_program(&program, &configs, observer, threads);
            return Ok((JobOutput { outcome, reports }, program));
        }

        // Model inputs: the same resolution as run(), shared so the
        // recorded numerics can never diverge from the live ones.
        let model_dense = input.model_dense_override();
        let mut owned = None;
        let jobs = resolve_model_input(input, &mut owned);
        screen_jobs(&jobs)?;
        let conv_dense: usize = jobs.iter().map(|(l, _)| l.numel()).sum();
        if cancel.is_cancelled() {
            return Err(JobError::Cancelled);
        }
        record_numerics_pass();
        let batch = pipeline::compress_layers_recorded(&jobs, &spec, threads, cancel)
            .ok_or(JobError::Cancelled)?;
        let outcome = aggregate(model_dense, conv_dense, batch.decomps, batch.max_rel_err);
        let program = JobProgram::from_outcome(batch.program, &outcome);
        let reports = cost_program(&program, &configs, observer, threads);
        Ok((JobOutput { outcome, reports }, program))
    }
}

/// Resolve a model-shaped [`Input`] to borrowed `(layer, tensor)`
/// jobs — shared by [`CompressionJob::run`] and
/// [`CompressionJob::program`] so the two paths cannot drift.
/// `owned` is the caller-kept backing store for synthetic workloads.
/// Panics on the `Tensor`/`Replay` variants (both handled earlier).
fn resolve_model_input<'a, 'b>(
    input: Input<'a>,
    owned: &'b mut Option<Vec<(ConvLayer, Tensor)>>,
) -> Vec<(&'b ConvLayer, &'b Tensor)>
where
    'a: 'b,
{
    match input {
        Input::Tensor(_) | Input::Replay(_) => unreachable!("handled above"),
        Input::Layers(layers) => layers.iter().map(|(l, w)| (l, w)).collect(),
        Input::Refs(jobs) => jobs,
        Input::Synthetic { seed, ratio, noise } => {
            *owned = Some(synthetic_model(seed, ratio, noise));
            owned.as_ref().expect("just set").iter().map(|(l, w)| (l, w)).collect()
        }
        Input::Transformer { spec, activations, seed } => {
            *owned = Some(if activations {
                spec.synthetic_activations(seed)
            } else {
                spec.synthetic_weights(seed)
            });
            owned.as_ref().expect("just set").iter().map(|(l, w)| (l, w)).collect()
        }
    }
}

/// NaN/Inf screening at the job input boundary (ISSUE 10): every
/// weight tensor is scanned before any numerics run, so a poisoned
/// workload fails with a structured [`JobError::NonFiniteInput`]
/// naming the offending layer instead of propagating non-finite
/// values through the decomposition. Single-tensor jobs screen as
/// layer 0; generated workloads (synthetic/transformer) are screened
/// post-materialization — their generators only emit finite values,
/// so on those inputs the screen can fire only under chaos poisoning.
fn screen_tensor(w: &Tensor, layer: usize) -> Result<(), JobError> {
    if w.data.iter().all(|v| v.is_finite()) {
        Ok(())
    } else {
        Err(JobError::NonFiniteInput { layer })
    }
}

/// Screen every layer of a resolved model input, in layer order —
/// the reported layer index is the first offender.
fn screen_jobs(jobs: &[(&ConvLayer, &Tensor)]) -> Result<(), JobError> {
    jobs.iter().enumerate().try_for_each(|(i, (_, w))| screen_tensor(w, i))
}

/// Whole-model accounting dispatch shared by [`CompressionJob::run`]
/// and [`CompressionJob::program`]: transformer inputs carry their own
/// inventory override; every other model-shaped input keeps the
/// legacy whole-ResNet-32 accounting.
fn aggregate(
    model_dense: Option<usize>,
    conv_dense: usize,
    decomps: Vec<crate::ttd::TtDecomp>,
    max_rel_err: f32,
) -> CompressionOutcome {
    match model_dense {
        Some(md) => aggregate_outcome_model(md, conv_dense, decomps, max_rel_err),
        None => aggregate_outcome_conv(conv_dense, decomps, max_rel_err),
    }
}

/// Digest one model layer for [`CompressionJob::cache_key`]: the full
/// conv shape (it fixes both the TT dims the tensor is reshaped to and
/// the dense-parameter accounting in the aggregate outcome) plus the
/// exact weight bits.
fn fingerprint_layer(fp: &mut Fingerprint, layer: &ConvLayer, w: &Tensor) {
    for &d in &layer.shape {
        fp.push_usize(d);
    }
    fp.push_f32s(&w.data);
}

/// Single-tensor accounting shared by [`CompressionJob::run`] and
/// [`CompressionJob::program`]: the "model" is just that tensor.
fn single_tensor_outcome(w: &Tensor, d: crate::ttd::TtDecomp) -> CompressionOutcome {
    let rel_err = relative_error(w, &d);
    let numel = w.numel();
    let tt = d.param_count();
    CompressionOutcome {
        decomps: vec![d],
        model_dense_params: numel,
        conv_dense_params: numel,
        conv_tt_params: tt,
        final_params: tt,
        compression_ratio: numel as f64 / tt as f64,
        max_rel_err: rel_err,
    }
}

/// Cost a program under a config bank (fast run-fold; the per-op tee
/// only when an observer needs the stream — all paths bit-identical).
/// `threads` is the job's `.parallel(..)` width: > 1 folds per-layer
/// segments concurrently via [`CostSink::fold_program_parallel`],
/// which falls back to the serial fold when any segment is not
/// self-phased. Observers always take the serial tee — they must see
/// the exact recorded op order.
fn cost_program(
    program: &JobProgram,
    configs: &[SocConfig],
    observer: Option<&mut dyn TraceSink>,
    threads: usize,
) -> Vec<SimReport> {
    let mut cost = CostSink::new(configs);
    match observer {
        Some(obs) => {
            let mut tee = Tee::new(&mut cost, obs);
            program.ops.replay(&mut tee);
        }
        None => cost.fold_program_parallel(&program.ops, threads),
    }
    cost.reports()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workload::compress_model;
    use crate::sim::SocConfig;
    use crate::trace::NullSink;
    use crate::util::Rng;

    fn small_model() -> Vec<(ConvLayer, Tensor)> {
        let mut layers = synthetic_model(5, 3.55, 0.035);
        layers.truncate(4);
        layers
    }

    #[test]
    fn single_tensor_job_matches_direct_decompose() {
        let mut rng = Rng::new(31);
        let w = Tensor::from_vec(&[4, 6, 6], rng.normal_vec(144));
        let direct = decompose(&w, &TtSpec::eps(0.2), &mut NullSink);
        let out = CompressionJob::new(&w).eps(0.2).run().unwrap();
        assert_eq!(out.decomp().ranks, direct.ranks);
        for (a, b) in out.decomp().cores.iter().zip(&direct.cores) {
            assert_eq!(a.data, b.data);
        }
        assert!(out.reports.is_empty());
        assert_eq!(out.outcome.model_dense_params, 144);
        assert_eq!(out.outcome.final_params, direct.param_count());
    }

    #[test]
    fn delta_is_an_alias_for_eps() {
        let mut rng = Rng::new(32);
        let w = Tensor::from_vec(&[4, 5, 5], rng.normal_vec(100));
        let a = CompressionJob::new(&w).eps(0.3).run().unwrap();
        let b = CompressionJob::new(&w).delta(0.3).run().unwrap();
        assert_eq!(a.decomp().ranks, b.decomp().ranks);
    }

    #[test]
    fn rank_cap_binds_every_bond() {
        let mut rng = Rng::new(33);
        let w = Tensor::from_vec(&[6, 6, 6], rng.normal_vec(216));
        let out = CompressionJob::new(&w).eps(0.0).rank_cap(2).run().unwrap();
        assert!(out.decomp().ranks.iter().all(|&r| r <= 2));
    }

    #[test]
    fn tuning_knobs_do_not_change_results() {
        // .kernel(Reference) and .hbd_threads(2) are raw-speed knobs:
        // every mode is bit-identical, so flipping them must leave
        // ranks, errors and reports untouched. (The knobs set process
        // globals; restore the defaults afterwards so sibling tests
        // see the standard configuration.)
        let layers = small_model();
        let configs = [SocConfig::tt_edge()];
        let want = CompressionJob::model(&layers).eps(0.12).socs(&configs).run().unwrap();
        let got = CompressionJob::model(&layers)
            .eps(0.12)
            .socs(&configs)
            .kernel(GemmKernel::Reference)
            .hbd_threads(2)
            .parallel(2)
            .run()
            .unwrap();
        set_gemm_kernel(GemmKernel::Vectorized);
        bidiag::set_panel_threads(1);
        assert_eq!(got.outcome.final_params, want.outcome.final_params);
        assert_eq!(got.outcome.max_rel_err, want.outcome.max_rel_err);
        for (a, b) in got.reports.iter().zip(&want.reports) {
            assert_eq!(a.total_ms, b.total_ms);
            assert_eq!(a.total_mj, b.total_mj);
        }
    }

    #[test]
    fn model_job_matches_legacy_compress_model() {
        let layers = small_model();
        let want = compress_model(&layers, 0.12, &mut NullSink);
        for threads in [1, 3] {
            let out = CompressionJob::model(&layers)
                .eps(0.12)
                .parallel(threads)
                .run()
                .unwrap();
            assert_eq!(out.outcome.final_params, want.final_params, "threads={threads}");
            assert_eq!(out.outcome.max_rel_err, want.max_rel_err);
            assert_eq!(out.outcome.compression_ratio, want.compression_ratio);
        }
    }

    #[test]
    fn streaming_reports_match_recorded_replay() {
        let layers = small_model();
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
        // recorded replay oracle
        let mut trace = crate::trace::VecSink::default();
        let _ = compress_model(&layers, 0.12, &mut trace);
        let mut replayed = CostSink::new(&configs);
        trace.replay(&mut replayed);
        let want = replayed.reports();
        for threads in [1, 2] {
            let out = CompressionJob::model(&layers)
                .eps(0.12)
                .parallel(threads)
                .socs(&configs)
                .run()
                .unwrap();
            assert_eq!(out.reports.len(), 2);
            for (a, b) in out.reports.iter().zip(&want) {
                assert_eq!(a.total_ms, b.total_ms, "threads={threads}");
                assert_eq!(a.total_mj, b.total_mj);
                for (pa, pb) in a.phases.iter().zip(&b.phases) {
                    assert_eq!(pa.cycles, pb.cycles, "{:?}", pa.phase);
                }
            }
        }
    }

    #[test]
    fn observer_sees_the_serial_trace_and_costs_stay_identical() {
        let layers = small_model();
        let mut serial = crate::trace::VecSink::default();
        let _ = compress_model(&layers, 0.12, &mut serial);
        for threads in [1, 3] {
            let mut observed = crate::trace::VecSink::default();
            let out = CompressionJob::model(&layers)
                .eps(0.12)
                .parallel(threads)
                .soc(SocConfig::tt_edge())
                .sink(&mut observed)
                .run()
                .unwrap();
            assert_eq!(observed.ops, serial.ops, "threads={threads}");
            // and the report equals the no-observer streaming run
            let plain = CompressionJob::model(&layers)
                .eps(0.12)
                .parallel(threads)
                .soc(SocConfig::tt_edge())
                .run()
                .unwrap();
            assert_eq!(out.reports[0].total_ms, plain.reports[0].total_ms);
            assert_eq!(out.reports[0].total_mj, plain.reports[0].total_mj);
        }
    }

    #[test]
    fn cancelled_job_returns_none() {
        let layers = small_model();
        let token = CancelToken::cancelled();
        let out = CompressionJob::model(&layers).cancel(&token).run();
        assert!(out.is_none());
        let mut rng = Rng::new(34);
        let w = Tensor::from_vec(&[4, 4, 4], rng.normal_vec(64));
        assert!(CompressionJob::new(&w).cancel(&token).run().is_none());
    }

    #[test]
    fn layer_refs_borrow_without_cloning() {
        let layers = small_model();
        let tensors: Vec<Tensor> = layers.iter().map(|(_, w)| w.clone()).collect();
        let jobs: Vec<(&ConvLayer, &Tensor)> =
            layers.iter().map(|(l, _)| l).zip(&tensors).collect();
        let out = CompressionJob::layer_refs(jobs)
            .eps(0.12)
            .soc(SocConfig::tt_edge())
            .run()
            .unwrap();
        let want = CompressionJob::model(&layers).eps(0.12).run().unwrap();
        assert_eq!(out.outcome.final_params, want.outcome.final_params);
        assert_eq!(out.reports.len(), 1);
        assert!(out.reports[0].total_ms > 0.0);
    }

    #[test]
    fn program_records_once_and_replays_bit_identically() {
        let layers = small_model();
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
        let live = CompressionJob::model(&layers)
            .eps(0.12)
            .socs(&configs)
            .run()
            .unwrap();
        let (rec_out, program) = CompressionJob::model(&layers)
            .eps(0.12)
            .socs(&configs)
            .program()
            .unwrap();
        // the recording run reports exactly what live costing reports
        for (a, b) in live.reports.iter().zip(&rec_out.reports) {
            assert_eq!(a.total_ms, b.total_ms);
            assert_eq!(a.total_mj, b.total_mj);
            for (pa, pb) in a.phases.iter().zip(&b.phases) {
                assert_eq!(pa.cycles, pb.cycles, "{:?}", pa.phase);
                assert_eq!(pa.energy_mj, pb.energy_mj);
            }
        }
        assert_eq!(rec_out.outcome.final_params, live.outcome.final_params);
        assert_eq!(rec_out.outcome.decomps.len(), layers.len());
        // ...and so does every subsequent replay, with no numerics
        let passes = super::numerics_pass_count();
        let replayed = CompressionJob::replay(&program).socs(&configs).run().unwrap();
        assert_eq!(super::numerics_pass_count(), passes, "replay ran numerics");
        for (a, b) in live.reports.iter().zip(&replayed.reports) {
            assert_eq!(a.total_ms, b.total_ms);
            assert_eq!(a.total_mj, b.total_mj);
            for (pa, pb) in a.phases.iter().zip(&b.phases) {
                assert_eq!(pa.cycles, pb.cycles, "{:?}", pa.phase);
            }
        }
        // replay outcomes carry the summary but no decompositions
        assert!(replayed.outcome.decomps.is_empty());
        assert_eq!(replayed.outcome.final_params, live.outcome.final_params);
        assert_eq!(replayed.outcome.max_rel_err, live.outcome.max_rel_err);
        assert_eq!(replayed.outcome.compression_ratio, live.outcome.compression_ratio);
    }

    #[test]
    fn program_observer_sees_the_serial_trace() {
        let layers = small_model();
        let mut serial = crate::trace::VecSink::default();
        let _ = compress_model(&layers, 0.12, &mut serial);
        for threads in [1, 3] {
            let mut observed = crate::trace::VecSink::default();
            let (_, program) = CompressionJob::model(&layers)
                .eps(0.12)
                .parallel(threads)
                .sink(&mut observed)
                .program()
                .unwrap();
            assert_eq!(observed.ops, serial.ops, "threads={threads}");
            assert_eq!(program.ops.op_count() as usize, serial.ops.len());
            // replaying into an observer reproduces the stream again
            let mut replayed = crate::trace::VecSink::default();
            let _ = CompressionJob::replay(&program).sink(&mut replayed).run().unwrap();
            assert_eq!(replayed.ops, serial.ops);
        }
    }

    #[test]
    fn run_counts_numerics_passes_and_replay_does_not() {
        let layers = small_model();
        let before = super::numerics_pass_count();
        let (_, program) = CompressionJob::model(&layers).eps(0.2).program().unwrap();
        assert_eq!(super::numerics_pass_count(), before + 1);
        let _ = CompressionJob::model(&layers).eps(0.2).run().unwrap();
        assert_eq!(super::numerics_pass_count(), before + 2);
        for _ in 0..3 {
            let _ = CompressionJob::replay(&program).soc(SocConfig::tt_edge()).run().unwrap();
        }
        assert_eq!(super::numerics_pass_count(), before + 2);
    }

    #[test]
    fn single_tensor_program_matches_its_run() {
        let mut rng = Rng::new(35);
        let w = Tensor::from_vec(&[4, 6, 6], rng.normal_vec(144));
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
        let live = CompressionJob::new(&w).eps(0.2).socs(&configs).run().unwrap();
        let (out, program) = CompressionJob::new(&w).eps(0.2).socs(&configs).program().unwrap();
        assert_eq!(out.decomp().ranks, live.decomp().ranks);
        assert_eq!(program.ops.layer_count(), 1);
        let replayed = CompressionJob::replay(&program).socs(&configs).run().unwrap();
        for (a, b) in live.reports.iter().zip(&replayed.reports) {
            assert_eq!(a.total_ms, b.total_ms);
            assert_eq!(a.total_mj, b.total_mj);
        }
    }

    #[test]
    fn cancelled_program_returns_none() {
        let layers = small_model();
        let token = CancelToken::cancelled();
        assert!(CompressionJob::model(&layers).cancel(&token).program().is_none());
        let mut rng = Rng::new(36);
        let w = Tensor::from_vec(&[4, 4, 4], rng.normal_vec(64));
        assert!(CompressionJob::new(&w).cancel(&token).program().is_none());
    }

    #[test]
    fn cached_run_hits_are_byte_identical_and_skip_numerics() {
        let layers = small_model();
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
        let cache = ProgramCache::new(8);
        let fresh = CompressionJob::model(&layers).eps(0.12).socs(&configs).run().unwrap();

        let before = super::numerics_pass_count();
        let miss = CompressionJob::model(&layers)
            .eps(0.12)
            .socs(&configs)
            .cached(&cache)
            .run()
            .unwrap();
        assert_eq!(super::numerics_pass_count(), before + 1, "miss records once");
        let hit = CompressionJob::model(&layers)
            .eps(0.12)
            .socs(&configs)
            .cached(&cache)
            .run()
            .unwrap();
        assert_eq!(super::numerics_pass_count(), before + 1, "hit must not run numerics");

        for out in [&miss, &hit] {
            assert_eq!(out.outcome.final_params, fresh.outcome.final_params);
            assert_eq!(out.outcome.max_rel_err, fresh.outcome.max_rel_err);
            assert_eq!(out.outcome.compression_ratio, fresh.outcome.compression_ratio);
            for (a, b) in out.reports.iter().zip(&fresh.reports) {
                assert_eq!(a.to_json().render(), b.to_json().render());
            }
        }
        // hit outputs carry the summary but no decompositions (the
        // replay contract — programs never store cores)
        assert!(hit.outcome.decomps.is_empty());
        let s = cache.stats();
        assert!(s.conserved(), "{s:?}");
        assert_eq!((s.lookups, s.hits, s.misses), (2, 1, 1));
    }

    #[test]
    fn cache_key_covers_rank_caps_not_just_eps() {
        let base = CompressionJob::synthetic(7).eps(0.12);
        let capped = CompressionJob::synthetic(7).eps(0.12).rank_cap(2);
        assert_ne!(
            base.cache_key(),
            capped.cache_key(),
            "two specs sharing eps but differing in rank caps must never collide"
        );
        // equivalent cap spellings canonicalize to one key
        let uniform = CompressionJob::synthetic(7).eps(0.12).rank_cap(2);
        let per_bond = CompressionJob::synthetic(7).eps(0.12).rank_caps(&[2, 2]);
        assert_eq!(uniform.cache_key(), per_bond.cache_key());
        // and the workload side is part of the key too
        assert_ne!(
            CompressionJob::synthetic(7).eps(0.12).cache_key(),
            CompressionJob::synthetic(8).eps(0.12).cache_key()
        );
    }

    #[test]
    fn layers_and_refs_share_a_cache_key_tensor_does_not() {
        let layers = small_model();
        let tensors: Vec<Tensor> = layers.iter().map(|(_, w)| w.clone()).collect();
        let jobs: Vec<(&ConvLayer, &Tensor)> =
            layers.iter().map(|(l, _)| l).zip(&tensors).collect();
        assert_eq!(
            CompressionJob::model(&layers).eps(0.12).cache_key(),
            CompressionJob::layer_refs(jobs).eps(0.12).cache_key(),
            "same content, same numerics, same key"
        );
        let mut rng = Rng::new(40);
        let w = Tensor::from_vec(&[4, 4, 4], rng.normal_vec(64));
        let w2 = {
            let mut t = w.clone();
            t.data[0] += 1.0;
            t
        };
        assert_ne!(
            CompressionJob::new(&w).eps(0.12).cache_key(),
            CompressionJob::new(&w2).eps(0.12).cache_key(),
            "one changed weight bit is a different workload"
        );
    }

    #[test]
    fn cancelled_cached_miss_returns_none_and_releases_the_key() {
        let layers = small_model();
        let cache = ProgramCache::new(8);
        let token = CancelToken::cancelled();
        assert!(CompressionJob::model(&layers).cached(&cache).cancel(&token).run().is_none());
        // the pending slot was released: a healthy run can now record
        let out = CompressionJob::model(&layers).cached(&cache).run();
        assert!(out.is_some());
        assert_eq!(cache.len(), 1);
        assert!(cache.stats().conserved());
    }

    #[test]
    fn transformer_job_uses_its_own_model_inventory() {
        let spec = TransformerSpec::tiny_gpt();
        let out = CompressionJob::transformer(spec, 3)
            .eps(0.12)
            .soc(SocConfig::tt_edge())
            .run()
            .unwrap();
        assert_eq!(out.outcome.decomps.len(), 12);
        assert_eq!(out.outcome.model_dense_params, spec.param_count());
        assert_eq!(out.outcome.conv_dense_params, spec.matrix_params());
        assert!(out.outcome.compression_ratio > 2.0, "{}", out.outcome.compression_ratio);
        assert!(out.reports[0].total_ms > 0.0);

        let acts = CompressionJob::transformer_activations(spec, 3).eps(0.12).run().unwrap();
        assert_eq!(acts.outcome.decomps.len(), 2);
        assert_eq!(acts.outcome.model_dense_params, spec.activation_count());
        assert_eq!(acts.outcome.conv_dense_params, spec.activation_count());
    }

    #[test]
    fn transformer_job_is_parallel_invariant_and_replays() {
        let spec = TransformerSpec::tiny_gpt();
        let serial = CompressionJob::transformer(spec, 4)
            .eps(0.12)
            .soc(SocConfig::tt_edge())
            .run()
            .unwrap();
        let wide = CompressionJob::transformer(spec, 4)
            .eps(0.12)
            .parallel(4)
            .soc(SocConfig::tt_edge())
            .run()
            .unwrap();
        assert_eq!(serial.outcome.final_params, wide.outcome.final_params);
        assert_eq!(serial.outcome.max_rel_err, wide.outcome.max_rel_err);
        assert_eq!(serial.reports[0].total_ms, wide.reports[0].total_ms);
        assert_eq!(serial.reports[0].total_mj, wide.reports[0].total_mj);
        // record-once / replay-many holds for the new workload too
        let (rec, program) = CompressionJob::transformer(spec, 4)
            .eps(0.12)
            .soc(SocConfig::tt_edge())
            .program()
            .unwrap();
        assert_eq!(rec.reports[0].total_ms, serial.reports[0].total_ms);
        let replayed =
            CompressionJob::replay(&program).soc(SocConfig::tt_edge()).run().unwrap();
        assert_eq!(replayed.reports[0].total_ms, serial.reports[0].total_ms);
        assert_eq!(replayed.outcome.final_params, serial.outcome.final_params);
    }

    #[test]
    fn transformer_cache_keys_split_on_variant_seed_and_spec() {
        let spec = TransformerSpec::tiny_gpt();
        let weights = CompressionJob::transformer(spec, 5).eps(0.12).cache_key();
        assert_ne!(
            weights,
            CompressionJob::transformer_activations(spec, 5).eps(0.12).cache_key(),
            "weight and activation variants are different workloads"
        );
        assert_ne!(weights, CompressionJob::transformer(spec, 6).eps(0.12).cache_key());
        assert_ne!(
            weights,
            CompressionJob::transformer(TransformerSpec::bert_base(), 5).eps(0.12).cache_key()
        );
        // deterministic: the same job builds the same key
        assert_eq!(weights, CompressionJob::transformer(spec, 5).eps(0.12).cache_key());
    }

    #[test]
    fn nan_tensor_is_rejected_as_layer_zero() {
        let mut rng = Rng::new(50);
        let mut w = Tensor::from_vec(&[4, 4, 4], rng.normal_vec(64));
        w.data[17] = f32::NAN;
        let before = super::numerics_pass_count();
        let err = CompressionJob::new(&w).eps(0.2).try_run().unwrap_err();
        assert_eq!(err, JobError::NonFiniteInput { layer: 0 });
        assert!(CompressionJob::new(&w).try_program().is_err());
        // the screen fires before the pass counter — no numerics ran
        assert_eq!(super::numerics_pass_count(), before);
        assert!(CompressionJob::new(&w).run().is_none(), "run() swallows the taxonomy");
    }

    #[test]
    fn model_screen_names_the_first_poisoned_layer() {
        let mut layers = small_model();
        layers[2].1.data[5] = f32::INFINITY;
        let err = CompressionJob::model(&layers).eps(0.12).try_run().unwrap_err();
        assert_eq!(err, JobError::NonFiniteInput { layer: 2 });
        assert_eq!(err.code(), "non-finite-input");
        assert!(!err.retryable(), "a poisoned input never heals on retry");
    }

    #[test]
    fn layer_ref_screen_names_the_first_poisoned_layer() {
        let layers = small_model();
        let mut tensors: Vec<Tensor> = layers.iter().map(|(_, w)| w.clone()).collect();
        tensors[1].data[0] = f32::NEG_INFINITY;
        let jobs: Vec<(&ConvLayer, &Tensor)> =
            layers.iter().map(|(l, _)| l).zip(&tensors).collect();
        let err = CompressionJob::layer_refs(jobs).eps(0.12).try_run().unwrap_err();
        assert_eq!(err, JobError::NonFiniteInput { layer: 1 });
    }

    #[test]
    fn generated_workloads_pass_the_input_screen() {
        // Synthetic and transformer generators only emit finite
        // weights, so the post-materialization screen is a no-op on
        // them — but poisoning the materialized weights (the serve
        // chaos path) trips the same screen through ::model.
        assert!(CompressionJob::synthetic(7).eps(0.3).try_run().is_ok());
        let spec = TransformerSpec::tiny_gpt();
        assert!(CompressionJob::transformer_activations(spec, 3).eps(0.3).try_run().is_ok());
        let mut weights = spec.synthetic_weights(3);
        weights[4].1.data[9] = f32::NAN;
        let err = CompressionJob::model(&weights).eps(0.3).try_run().unwrap_err();
        assert_eq!(err, JobError::NonFiniteInput { layer: 4 });
    }

    #[test]
    fn cancellation_maps_to_the_structured_error() {
        let layers = small_model();
        let token = CancelToken::cancelled();
        let err = CompressionJob::model(&layers).cancel(&token).try_run().unwrap_err();
        assert_eq!(err, JobError::Cancelled);
        let err = CompressionJob::model(&layers).cancel(&token).try_program().unwrap_err();
        assert_eq!(err, JobError::Cancelled);
    }

    #[test]
    fn rejected_cached_miss_releases_the_pending_slot() {
        let mut layers = small_model();
        layers[0].1.data[0] = f32::NAN;
        let cache = ProgramCache::new(8);
        let err = CompressionJob::model(&layers).cached(&cache).try_run().unwrap_err();
        assert_eq!(err, JobError::NonFiniteInput { layer: 0 });
        // the pending slot was released, not leaked: the same poisoned
        // key can be claimed (and rejected) again, and the stats stay
        // conserved with nothing resident
        let err = CompressionJob::model(&layers).cached(&cache).try_run().unwrap_err();
        assert_eq!(err, JobError::NonFiniteInput { layer: 0 });
        assert_eq!(cache.len(), 0);
        let s = cache.stats();
        assert!(s.conserved(), "{s:?}");
        assert_eq!((s.lookups, s.misses, s.hits), (2, 2, 0));
    }

    #[test]
    fn synthetic_job_matches_compress_resnet32() {
        let (want_out, want_reports) = crate::sim::workload::compress_resnet32(
            9,
            0.12,
            &[SocConfig::baseline(), SocConfig::tt_edge()],
        );
        let got = CompressionJob::synthetic(9)
            .eps(0.12)
            .parallel(2)
            .socs(&[SocConfig::baseline(), SocConfig::tt_edge()])
            .run()
            .unwrap();
        assert_eq!(got.outcome.final_params, want_out.final_params);
        for (a, b) in got.reports.iter().zip(&want_reports) {
            assert_eq!(a.total_ms, b.total_ms);
            assert_eq!(a.total_mj, b.total_mj);
        }
    }
}
