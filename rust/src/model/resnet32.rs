//! ResNet-32 (CIFAR-10) parameter inventory — the paper's benchmark
//! model (Table I: 0.47 M parameters uncompressed).
//!
//! The layout mirrors `python/compile/resnet.py::param_specs()` *exactly*
//! (same names, same order): the rust side must marshal parameters to
//! the AOT-exported `resnet32_fwd_b4` / `resnet32_sgd_b8` artifacts in
//! this order.

/// One parameter array in the canonical flat order.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A conv layer eligible for TTD compression.
#[derive(Clone, Debug)]
pub struct ConvLayer {
    /// Index into the flat parameter list.
    pub param_index: usize,
    pub name: String,
    /// (kh, kw, cin, cout) — HWIO, as the JAX side.
    pub shape: [usize; 4],
}

impl ConvLayer {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// The TT factorization dims used throughout: (kh*kw, cin, cout)
    /// — the TIE/ETTE conv layout (DESIGN.md section 3).
    pub fn tt_dims(&self) -> [usize; 3] {
        [self.shape[0] * self.shape[1], self.shape[2], self.shape[3]]
    }
}

pub const BLOCKS_PER_STAGE: usize = 5;
pub const STAGE_CHANNELS: [usize; 3] = [16, 32, 64];
pub const NUM_CLASSES: usize = 10;

/// Ordered parameter list — must match python `param_specs()`.
pub fn param_specs() -> Vec<ParamSpec> {
    let mut specs = vec![
        ParamSpec { name: "conv_init/w".into(), shape: vec![3, 3, 3, 16] },
        ParamSpec { name: "bn_init/scale".into(), shape: vec![16] },
        ParamSpec { name: "bn_init/bias".into(), shape: vec![16] },
    ];
    let mut in_ch = 16;
    for (s, &ch) in STAGE_CHANNELS.iter().enumerate() {
        for b in 0..BLOCKS_PER_STAGE {
            let c_in = if b == 0 { in_ch } else { ch };
            let p = format!("stage{s}/block{b}");
            specs.push(ParamSpec { name: format!("{p}/conv1/w"), shape: vec![3, 3, c_in, ch] });
            specs.push(ParamSpec { name: format!("{p}/bn1/scale"), shape: vec![ch] });
            specs.push(ParamSpec { name: format!("{p}/bn1/bias"), shape: vec![ch] });
            specs.push(ParamSpec { name: format!("{p}/conv2/w"), shape: vec![3, 3, ch, ch] });
            specs.push(ParamSpec { name: format!("{p}/bn2/scale"), shape: vec![ch] });
            specs.push(ParamSpec { name: format!("{p}/bn2/bias"), shape: vec![ch] });
        }
        in_ch = ch;
    }
    specs.push(ParamSpec {
        name: "fc/w".into(),
        shape: vec![STAGE_CHANNELS[2], NUM_CLASSES],
    });
    specs.push(ParamSpec { name: "fc/b".into(), shape: vec![NUM_CLASSES] });
    specs
}

/// Total parameter count (Table I "Uncompressed": ~0.47 M).
pub fn param_count() -> usize {
    param_specs().iter().map(|s| s.numel()).sum()
}

/// The 31 conv kernels — the TTD compression targets.
pub fn conv_layers() -> Vec<ConvLayer> {
    param_specs()
        .iter()
        .enumerate()
        .filter(|(_, s)| s.shape.len() == 4)
        .map(|(i, s)| ConvLayer {
            param_index: i,
            name: s.name.clone(),
            shape: [s.shape[0], s.shape[1], s.shape[2], s.shape[3]],
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_matches_table1_uncompressed() {
        let n = param_count();
        assert!((440_000..480_000).contains(&n), "{n}");
        // exact value pinned against the python side (test_resnet.py)
        assert_eq!(n, 464_154);
    }

    #[test]
    fn thirty_one_conv_layers() {
        let convs = conv_layers();
        assert_eq!(convs.len(), 31);
        assert_eq!(convs[0].shape, [3, 3, 3, 16]);
        assert_eq!(convs.last().unwrap().shape, [3, 3, 64, 64]);
    }

    #[test]
    fn spec_order_matches_python_layout() {
        let specs = param_specs();
        assert_eq!(specs[0].name, "conv_init/w");
        assert_eq!(specs[3].name, "stage0/block0/conv1/w");
        assert_eq!(specs.last().unwrap().name, "fc/b");
        // 3 stem + 15 blocks * 6 + 2 fc
        assert_eq!(specs.len(), 3 + 15 * 6 + 2);
    }

    #[test]
    fn tt_dims_factorization() {
        let convs = conv_layers();
        let l = convs.last().unwrap();
        assert_eq!(l.tt_dims(), [9, 64, 64]);
        assert_eq!(l.tt_dims().iter().product::<usize>(), l.numel());
    }

    #[test]
    fn stage_transition_shapes() {
        let convs = conv_layers();
        // stage1/block0/conv1 takes 16 -> 32
        let t = convs.iter().find(|c| c.name == "stage1/block0/conv1/w").unwrap();
        assert_eq!(t.shape, [3, 3, 16, 32]);
        let t = convs.iter().find(|c| c.name == "stage2/block0/conv1/w").unwrap();
        assert_eq!(t.shape, [3, 3, 32, 64]);
    }
}
