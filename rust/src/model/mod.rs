//! The compression workloads: ResNet-32 parameter inventory and
//! store, plus transformer-scale decoder stacks and activation maps
//! (ISSUE 9).

pub mod params;
pub mod resnet32;
pub mod transformer;

pub use params::ParamStore;
pub use resnet32::{conv_layers, param_count, param_specs, ConvLayer};
pub use transformer::TransformerSpec;
