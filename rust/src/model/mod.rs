//! The compression workload: ResNet-32 parameter inventory and store.

pub mod params;
pub mod resnet32;

pub use params::ParamStore;
pub use resnet32::{conv_layers, param_count, param_specs, ConvLayer};
