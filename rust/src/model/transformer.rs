//! Transformer-scale compression workloads (ISSUE 9, after arXiv
//! 2501.19135's TTD-compressed LLM layers and arXiv 2411.06346's
//! activation-map compression).
//!
//! A [`TransformerSpec`] is a parameterized decoder block stack: per
//! layer the four attention projections (`Wq`/`Wk`/`Wv`/`Wo`, each
//! `d_model x d_model`) and the FFN up/down pair (`d_model x d_ff` /
//! `d_ff x d_model`). Every matrix is carried as a [`ConvLayer`] with
//! a unit spatial extent — its `tt_dims()` become `[f1, f2, cols]`
//! for a balanced factorization `f1 * f2 = rows` — so the whole
//! existing pipeline (job builder, per-layer fan-out, program cache,
//! serve wire format) consumes transformer workloads unchanged.
//!
//! Weights are *trained-like* via the same planted-TT-rank generator
//! the ResNet workload uses ([`synthetic_trained_conv`]); the
//! activation-map variant plants per-layer `seq_len x d_model`
//! activation stacks instead (activations are the compression target
//! in the 2411.06346 setting, not the weights).

use crate::model::resnet32::ConvLayer;
use crate::sim::workload::synthetic_trained_conv;
use crate::ttd::Tensor;
use crate::util::Rng;

/// Planted compression ratio / relative noise for transformer weight
/// matrices (LLM projections are strongly low-rank in the 2501.19135
/// setting).
pub const WEIGHT_RATIO: f64 = 6.0;
pub const WEIGHT_NOISE: f32 = 0.02;

/// Planted ratio / noise for activation maps (2411.06346 compresses
/// them harder than weights).
pub const ACTIVATION_RATIO: f64 = 8.0;
pub const ACTIVATION_NOISE: f32 = 0.02;

/// A decoder-block stack: `layers` blocks of QKV/O projections plus
/// an FFN up/down pair at (`d_model`, `d_ff`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransformerSpec {
    pub name: &'static str,
    pub d_model: usize,
    pub d_ff: usize,
    pub layers: usize,
    /// Sequence length of the activation-map variant.
    pub seq_len: usize,
}

/// Balanced two-factor split of `n`: the largest divisor pair
/// `(a, b)` with `a <= b` and `a * b = n`.
pub fn balanced_factor(n: usize) -> (usize, usize) {
    let mut a = (n.max(1) as f64).sqrt() as usize;
    while a > 1 && n % a != 0 {
        a -= 1;
    }
    let a = a.max(1);
    (a, n / a)
}

/// One `rows x cols` matrix as a unit-spatial [`ConvLayer`] whose
/// `tt_dims()` are `[f1, f2, cols]` with `f1 * f2 = rows`.
fn matrix_layer(param_index: usize, name: String, rows: usize, cols: usize) -> ConvLayer {
    let (f1, f2) = balanced_factor(rows);
    ConvLayer { param_index, name, shape: [1, f1, f2, cols] }
}

impl TransformerSpec {
    /// A test-fast decoder stack (the CI smoke workload).
    pub fn tiny_gpt() -> Self {
        TransformerSpec { name: "tiny-gpt", d_model: 64, d_ff: 256, layers: 2, seq_len: 32 }
    }

    /// BERT-base scale: 12 blocks at (768, 3072) — ~85 M matrix
    /// parameters. Shape-enumerable everywhere; decomposing it is a
    /// dedicated-hardware run, not a CI job.
    pub fn bert_base() -> Self {
        TransformerSpec { name: "bert-base", d_model: 768, d_ff: 3072, layers: 12, seq_len: 128 }
    }

    /// The TTD-compressible weight matrices, in canonical order
    /// (`layer{i}/{wq,wk,wv,wo,ffn_up,ffn_down}`).
    pub fn weight_layers(&self) -> Vec<ConvLayer> {
        let mut out = Vec::with_capacity(self.layers * 6);
        for i in 0..self.layers {
            for proj in ["wq", "wk", "wv", "wo"] {
                out.push(matrix_layer(
                    out.len(),
                    format!("layer{i}/{proj}"),
                    self.d_model,
                    self.d_model,
                ));
            }
            out.push(matrix_layer(
                out.len(),
                format!("layer{i}/ffn_up"),
                self.d_model,
                self.d_ff,
            ));
            out.push(matrix_layer(
                out.len(),
                format!("layer{i}/ffn_down"),
                self.d_ff,
                self.d_model,
            ));
        }
        out
    }

    /// The activation-map variant: one `seq_len x d_model` activation
    /// stack per block output.
    pub fn activation_layers(&self) -> Vec<ConvLayer> {
        (0..self.layers)
            .map(|i| {
                matrix_layer(i, format!("layer{i}/act"), self.seq_len, self.d_model)
            })
            .collect()
    }

    /// Dense matrix parameters (the compression targets).
    pub fn matrix_params(&self) -> usize {
        self.layers * (4 * self.d_model * self.d_model + 2 * self.d_model * self.d_ff)
    }

    /// Whole-model inventory: matrices + projection/FFN biases + the
    /// per-block and final layernorm affines — the uncompressed
    /// remainder in the aggregate accounting, mirroring how the
    /// ResNet path counts its bn/fc parameters.
    pub fn param_count(&self) -> usize {
        let per_block_small = 4 * self.d_model // proj biases
            + self.d_ff                        // ffn_up bias
            + self.d_model                     // ffn_down bias
            + 4 * self.d_model; // two layernorm affines
        self.matrix_params() + self.layers * per_block_small + 2 * self.d_model
    }

    /// Whole-"model" inventory of the activation variant: just the
    /// activation stacks.
    pub fn activation_count(&self) -> usize {
        self.layers * self.seq_len * self.d_model
    }

    /// Generate the trained-like weight workload (seeded, per-matrix
    /// forked streams like the ResNet generator).
    pub fn synthetic_weights(&self, seed: u64) -> Vec<(ConvLayer, Tensor)> {
        materialize(self.weight_layers(), seed, WEIGHT_RATIO, WEIGHT_NOISE)
    }

    /// Generate the activation-map workload.
    pub fn synthetic_activations(&self, seed: u64) -> Vec<(ConvLayer, Tensor)> {
        materialize(self.activation_layers(), seed, ACTIVATION_RATIO, ACTIVATION_NOISE)
    }
}

fn materialize(
    layers: Vec<ConvLayer>,
    seed: u64,
    ratio: f64,
    noise: f32,
) -> Vec<(ConvLayer, Tensor)> {
    let rng = Rng::new(seed);
    layers
        .into_iter()
        .map(|l| {
            let mut child = rng.fork(l.param_index as u64);
            let w = synthetic_trained_conv(&mut child, &l, ratio, noise);
            (l, w)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;
    use crate::ttd::{decompose, TtSpec};

    #[test]
    fn balanced_factors() {
        assert_eq!(balanced_factor(64), (8, 8));
        assert_eq!(balanced_factor(768), (24, 32));
        assert_eq!(balanced_factor(3072), (48, 64));
        assert_eq!(balanced_factor(32), (4, 8));
        assert_eq!(balanced_factor(7), (1, 7));
        assert_eq!(balanced_factor(1), (1, 1));
    }

    #[test]
    fn tiny_gpt_inventory() {
        let t = TransformerSpec::tiny_gpt();
        let ws = t.weight_layers();
        assert_eq!(ws.len(), 2 * 6);
        assert_eq!(ws[0].tt_dims(), [8, 8, 64]);
        assert_eq!(ws[4].tt_dims(), [8, 8, 256]); // ffn_up
        assert_eq!(ws[5].tt_dims(), [16, 16, 64]); // ffn_down
        let dense: usize = ws.iter().map(|l| l.numel()).sum();
        assert_eq!(dense, t.matrix_params());
        assert!(t.param_count() > t.matrix_params());
        // param indices are the rng fork streams: dense and unique
        for (i, l) in ws.iter().enumerate() {
            assert_eq!(l.param_index, i);
        }
    }

    #[test]
    fn bert_base_is_bert_scale() {
        let b = TransformerSpec::bert_base();
        assert_eq!(b.weight_layers().len(), 72);
        // 12 * (4*768^2 + 2*768*3072) = ~85 M
        assert_eq!(b.matrix_params(), 84_934_656);
        assert_eq!(b.weight_layers()[0].tt_dims(), [24, 32, 768]);
    }

    #[test]
    fn activation_variant_shapes() {
        let t = TransformerSpec::tiny_gpt();
        let acts = t.activation_layers();
        assert_eq!(acts.len(), 2);
        assert_eq!(acts[0].tt_dims(), [4, 8, 64]);
        assert_eq!(t.activation_count(), 2 * 32 * 64);
    }

    #[test]
    fn synthetic_weights_are_seeded_and_compressible() {
        let t = TransformerSpec::tiny_gpt();
        let a = t.synthetic_weights(7);
        let b = t.synthetic_weights(7);
        assert_eq!(a.len(), 12);
        for ((_, wa), (_, wb)) in a.iter().zip(&b) {
            assert_eq!(wa.data, wb.data);
        }
        let c = t.synthetic_weights(8);
        assert_ne!(a[0].1.data, c[0].1.data);
        // the planted structure makes prescribed-accuracy TTD land
        // near the planted ratio
        let (l, w) = &a[0];
        let d = decompose(&w.reshape(&l.tt_dims()), &TtSpec::eps(0.12), &mut NullSink);
        assert!(d.compression_ratio() > 3.0, "ratio {}", d.compression_ratio());
    }

    #[test]
    fn synthetic_activations_are_compressible() {
        let t = TransformerSpec::tiny_gpt();
        let acts = t.synthetic_activations(5);
        let (l, w) = &acts[0];
        let d = decompose(&w.reshape(&l.tt_dims()), &TtSpec::eps(0.12), &mut NullSink);
        assert!(d.compression_ratio() > 3.0, "ratio {}", d.compression_ratio());
    }
}
