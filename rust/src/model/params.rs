//! Parameter store: holds a full model's parameter arrays in the
//! canonical flat order, with He initialization (mirroring
//! `python/compile/resnet.py::init_params`) and simple binary
//! save/load for experiment reproducibility.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::resnet32::{param_specs, ParamSpec};
use crate::ttd::Tensor;
use crate::util::Rng;

/// A model's parameters in canonical order.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub specs: Vec<ParamSpec>,
    pub values: Vec<Tensor>,
}

impl ParamStore {
    /// He-normal initialized ResNet-32 parameters (bn2 scales zeroed —
    /// identical policy to the python init, see resnet.py).
    pub fn init_resnet32(seed: u64) -> Self {
        let specs = param_specs();
        let mut rng = Rng::new(seed);
        let values = specs
            .iter()
            .map(|s| {
                let n = s.numel();
                let data: Vec<f32> = if s.shape.len() == 4 {
                    let fan_in = (s.shape[0] * s.shape[1] * s.shape[2]) as f64;
                    let std = (2.0 / fan_in).sqrt();
                    (0..n).map(|_| (rng.normal() * std) as f32).collect()
                } else if s.name == "fc/w" {
                    let std = (1.0 / s.shape[0] as f64).sqrt();
                    (0..n).map(|_| (rng.normal() * std) as f32).collect()
                } else if s.name.ends_with("bn2/scale") {
                    vec![0.0; n]
                } else if s.name.ends_with("/scale") {
                    vec![1.0; n]
                } else {
                    vec![0.0; n]
                };
                Tensor::from_vec(&s.shape, data)
            })
            .collect();
        Self { specs, values }
    }

    pub fn total_params(&self) -> usize {
        self.values.iter().map(|t| t.numel()).sum()
    }

    pub fn by_name(&self, name: &str) -> Option<&Tensor> {
        self.specs
            .iter()
            .position(|s| s.name == name)
            .map(|i| &self.values[i])
    }

    /// Flat f32 view in canonical order (for aggregation / diffing).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.total_params());
        for t in &self.values {
            out.extend_from_slice(&t.data);
        }
        out
    }

    /// Inverse of [`ParamStore::flatten`].
    pub fn unflatten_into(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.total_params());
        let mut off = 0;
        for t in &mut self.values {
            let n = t.numel();
            t.data.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
    }

    /// Binary format: magic, count, then per-tensor rank/dims/data.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(b"TTEP")?;
        f.write_all(&(self.values.len() as u32).to_le_bytes())?;
        for t in &self.values {
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for d in &t.shape {
                f.write_all(&(*d as u32).to_le_bytes())?;
            }
            let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        let mut off = 0usize;
        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            if *off + n > buf.len() {
                bail!("truncated param file");
            }
            let s = &buf[*off..*off + n];
            *off += n;
            Ok(s)
        };
        if take(&mut off, 4)? != b"TTEP" {
            bail!("bad magic");
        }
        let count = u32::from_le_bytes(take(&mut off, 4)?.try_into()?) as usize;
        let mut values = Vec::with_capacity(count);
        for _ in 0..count {
            let rank = u32::from_le_bytes(take(&mut off, 4)?.try_into()?) as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(u32::from_le_bytes(take(&mut off, 4)?.try_into()?) as usize);
            }
            let n: usize = shape.iter().product();
            let raw = take(&mut off, 4 * n)?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            values.push(Tensor::from_vec(&shape, data));
        }
        let specs = param_specs();
        if specs.len() != values.len() {
            bail!("param count mismatch: {} vs {}", specs.len(), values.len());
        }
        Ok(Self { specs, values })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_has_canonical_count() {
        let p = ParamStore::init_resnet32(1);
        assert_eq!(p.total_params(), 464_154);
        assert_eq!(p.values.len(), p.specs.len());
    }

    #[test]
    fn init_statistics_follow_he() {
        let p = ParamStore::init_resnet32(2);
        let w = p.by_name("stage2/block2/conv1/w").unwrap();
        let fan_in = (3 * 3 * 64) as f64;
        let var: f64 =
            w.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / w.numel() as f64;
        assert!((var - 2.0 / fan_in).abs() < 0.5 * 2.0 / fan_in, "var {var}");
        // bn2 scales start at zero (identity residual blocks)
        let s = p.by_name("stage0/block0/bn2/scale").unwrap();
        assert!(s.data.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn deterministic_by_seed() {
        let a = ParamStore::init_resnet32(7);
        let b = ParamStore::init_resnet32(7);
        assert_eq!(a.flatten(), b.flatten());
        let c = ParamStore::init_resnet32(8);
        assert_ne!(a.flatten(), c.flatten());
    }

    #[test]
    fn flatten_roundtrip() {
        let mut p = ParamStore::init_resnet32(3);
        let mut flat = p.flatten();
        for v in flat.iter_mut() {
            *v *= 2.0;
        }
        p.unflatten_into(&flat);
        assert_eq!(p.flatten(), flat);
    }

    #[test]
    fn save_load_roundtrip() {
        let p = ParamStore::init_resnet32(4);
        let dir = std::env::temp_dir().join("tt_edge_test_params.bin");
        p.save(&dir).unwrap();
        let q = ParamStore::load(&dir).unwrap();
        assert_eq!(p.flatten(), q.flatten());
        let _ = std::fs::remove_file(dir);
    }
}
