"""AOT export invariants: the artifact contract the rust runtime relies on."""

import json
import pathlib

import jax
import pytest

from compile import aot

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_entry_registry_complete():
    expected = {
        "house_left_128",
        "house_right_128",
        "gemm_256",
        "norm_4096",
        "svd_144x64",
        "ttd3_conv64",
        "tt_rec3_conv64",
        "resnet32_fwd_b4",
        "resnet32_sgd_b8",
    }
    assert set(aot.ENTRIES) == expected


@pytest.mark.parametrize("name", ["house_left_128", "norm_4096"])
def test_small_entries_lower_without_custom_calls(name):
    """interpret=True pallas must lower to plain HLO (rust CPU-runnable)."""
    fn, args, _ = aot.ENTRIES[name]()
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "ENTRY" in text
    assert "custom-call" not in text


def test_manifest_matches_registry_when_present():
    """If `make artifacts` has run, the manifest must be complete & sane."""
    mpath = ARTIFACTS / "manifest.json"
    if not mpath.exists():
        pytest.skip("artifacts not built yet (run `make artifacts`)")
    manifest = json.loads(mpath.read_text())
    names = {e["name"] for e in manifest["entries"]}
    assert names == set(aot.ENTRIES)
    for e in manifest["entries"]:
        f = ARTIFACTS / e["file"]
        assert f.exists(), f"missing artifact {e['file']}"
        assert e["inputs"] and e["outputs"]
        for spec in e["inputs"] + e["outputs"]:
            assert spec["dtype"] in ("float32", "int32")
            assert all(isinstance(d, int) for d in spec["shape"])


def test_manifest_resnet_arity():
    mpath = ARTIFACTS / "manifest.json"
    if not mpath.exists():
        pytest.skip("artifacts not built yet")
    manifest = {e["name"]: e for e in json.loads(mpath.read_text())["entries"]}
    fwd = manifest["resnet32_fwd_b4"]
    # 95 parameter arrays + 1 input image batch
    assert len(fwd["inputs"]) == 96
    assert fwd["outputs"][0]["shape"] == [4, 10]
    sgd = manifest["resnet32_sgd_b8"]
    assert len(sgd["outputs"]) == len(sgd["inputs"]) - 2  # params' + loss
