"""L2 SVD pipeline: masked HBD + one-sided Jacobi vs LAPACK."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given

from compile.kernels import ref
from compile.svd import hbd, jacobi_svd, svd, svd_tall

hypothesis.settings.register_profile(
    "svd", deadline=None, max_examples=12, derandomize=True
)
hypothesis.settings.load_profile("svd")


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


# ----------------------------------------------------------------- hbd


@given(
    m=st.integers(min_value=2, max_value=48),
    n=st.integers(min_value=2, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hbd_factorization_properties(m, n, seed):
    """A = U_B B V_B^T with bidiagonal B and orthogonal factors."""
    if m < n:
        m, n = n, m
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, n))
    u, b, vt = hbd(a)
    scale = float(np.linalg.norm(np.array(a))) + 1e-6
    # reconstruction
    err = np.abs(np.array(u @ b @ vt) - np.array(a)).max() / scale
    assert err < 5e-5, f"reconstruction error {err}"
    # bidiagonal structure (exact: the cleanup writes zeros)
    bn = np.array(b)
    off = bn - np.triu(np.tril(bn, 1))
    assert np.abs(off).max() == 0.0
    # orthogonality
    assert np.abs(np.array(u.T @ u) - np.eye(n)).max() < 5e-5
    assert np.abs(np.array(vt @ vt.T) - np.eye(n)).max() < 5e-5


def test_hbd_matches_dense_reference():
    """Same bidiagonal (up to sign) as the straight-line oracle."""
    rng = np.random.default_rng(5)
    a = _rand(rng, (20, 10))
    _, b1, _ = hbd(a)
    _, b2, _ = ref.hbd_reference(a)
    np.testing.assert_allclose(
        np.abs(np.diag(np.array(b1))), np.abs(np.diag(np.array(b2))), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.abs(np.diag(np.array(b1), 1)), np.abs(np.diag(np.array(b2), 1)), rtol=1e-4, atol=1e-4
    )


def test_hbd_on_rank_deficient_input():
    """Zero tail columns exercise the degenerate-HOUSE guard."""
    rng = np.random.default_rng(6)
    a = np.zeros((16, 8), np.float32)
    a[:, :3] = rng.standard_normal((16, 3))
    u, b, vt = hbd(jnp.asarray(a))
    err = np.abs(np.array(u @ b @ vt) - a).max()
    assert err < 1e-4
    assert np.isfinite(np.array(b)).all()


def test_hbd_singular_values_preserved():
    """HBD is orthogonal-equivalent: B has A's singular values."""
    rng = np.random.default_rng(8)
    a = _rand(rng, (30, 12))
    _, b, _ = hbd(a)
    s_a = np.linalg.svd(np.array(a), compute_uv=False)
    s_b = np.linalg.svd(np.array(b), compute_uv=False)
    np.testing.assert_allclose(s_a, s_b, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- jacobi


@given(
    n=st.integers(min_value=2, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_jacobi_svd_matches_lapack(n, seed):
    rng = np.random.default_rng(seed)
    b = _rand(rng, (n, n))
    u, s, vt = jacobi_svd(b)
    s_ref = np.linalg.svd(np.array(b), compute_uv=False)
    np.testing.assert_allclose(np.array(s), s_ref, rtol=1e-3, atol=1e-4)
    # descending order (the Sorting phase)
    sn = np.array(s)
    assert (np.diff(sn) <= 1e-5).all()
    # factorization
    np.testing.assert_allclose(
        np.array((u * s) @ vt), np.array(b), rtol=1e-3, atol=1e-3
    )


def test_jacobi_identity():
    u, s, vt = jacobi_svd(jnp.eye(6, dtype=jnp.float32))
    np.testing.assert_allclose(np.array(s), np.ones(6), rtol=1e-6)


# ----------------------------------------------------------------- svd


@given(
    m=st.integers(min_value=2, max_value=40),
    n=st.integers(min_value=2, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_svd_economy_any_aspect(m, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, n))
    u, s, vt = svd(a)
    k = min(m, n)
    assert u.shape == (m, k) and s.shape == (k,) and vt.shape == (k, n)
    s_ref = np.linalg.svd(np.array(a), compute_uv=False)
    np.testing.assert_allclose(np.array(s), s_ref, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(
        np.array((u * s) @ vt), np.array(a), rtol=1e-3, atol=1e-3
    )


def test_svd_tall_orthogonal_factors():
    rng = np.random.default_rng(9)
    a = _rand(rng, (64, 24))
    u, s, vt = svd_tall(a)
    assert np.abs(np.array(u.T @ u) - np.eye(24)).max() < 2e-4
    assert np.abs(np.array(vt @ vt.T) - np.eye(24)).max() < 2e-4
