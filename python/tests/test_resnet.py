"""ResNet-32 workload: shapes, parameter budget, trainability."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import resnet


def test_param_count_matches_table1():
    """Table I: uncompressed ResNet-32 has ~0.47 M parameters."""
    n = resnet.param_count()
    assert 0.44e6 < n < 0.48e6, n


def test_param_specs_cover_init():
    params = resnet.init_params(jax.random.PRNGKey(0))
    specs = resnet.param_specs()
    assert len(params) == len(specs)
    for p, (_, shape) in zip(params, specs):
        assert p.shape == shape


def test_conv_specs_are_the_ttd_targets():
    convs = resnet.conv_param_specs()
    # 1 stem + 2 per block * 15 blocks
    assert len(convs) == 31
    assert all(len(s) == 4 for _, s in convs)


def test_forward_shape_and_finiteness():
    params = resnet.init_params(jax.random.PRNGKey(1))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 32, 32, 3)), jnp.float32)
    logits = resnet.forward(params, x)
    assert logits.shape == (4, 10)
    assert bool(jnp.isfinite(logits).all())


def test_forward_batch_invariance():
    """Row k of a batched forward equals the single-sample forward."""
    params = resnet.init_params(jax.random.PRNGKey(2))
    x = jnp.asarray(np.random.default_rng(1).standard_normal((3, 32, 32, 3)), jnp.float32)
    full = resnet.forward(params, x)
    one = resnet.forward(params, x[1:2])
    np.testing.assert_allclose(np.array(full[1]), np.array(one[0]), rtol=1e-4, atol=1e-4)


def test_sgd_memorizes_tiny_batch():
    """A few steps on one batch must reduce the loss (trainability)."""
    params = resnet.init_params(jax.random.PRNGKey(3))
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((8, 32, 32, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, 8), jnp.int32)
    step = jax.jit(resnet.sgd_step, static_argnames=())
    losses = []
    for _ in range(8):
        params, loss = step(params, x, y, 0.02)
        losses.append(float(loss))
    assert np.isfinite(losses).all(), losses
    assert losses[-1] < losses[0], losses


def test_shortcut_option_a_param_free():
    """Option-A shortcuts add no parameters (keeps the 0.47 M budget)."""
    names = [n for n, _ in resnet.param_specs()]
    assert not any("shortcut" in n or "proj" in n for n in names)
