"""Algorithm 1 (TTD) on padded fixed shapes + Eq. (1)/(2) reconstruction."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given

from compile import model
from compile.ttd import delta_threshold, tt_reconstruct, ttd3, ttd4, ttd_step

hypothesis.settings.register_profile(
    "ttd", deadline=None, max_examples=8, derandomize=True
)
hypothesis.settings.load_profile("ttd")


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


def _rel(a, b):
    return float(np.linalg.norm(np.array(a) - np.array(b)) / np.linalg.norm(np.array(b)))


# ------------------------------------------------------------ ttd_step


def test_ttd_step_splits_svd():
    """g @ w_next must reproduce the input up to the truncation budget."""
    rng = np.random.default_rng(0)
    w = _rand(rng, (24, 18))
    delta = jnp.asarray(0.0, jnp.float32)
    g, w_next, r = ttd_step(w, delta, 18)
    assert int(r) == 18
    np.testing.assert_allclose(np.array(g @ w_next), np.array(w), rtol=1e-3, atol=1e-3)


def test_ttd_step_padding_is_exact_zero():
    """Columns/rows beyond the retained rank are *exactly* zero."""
    rng = np.random.default_rng(1)
    # rank-3 matrix => hard truncation with tiny delta
    a = rng.standard_normal((20, 3)) @ rng.standard_normal((3, 15))
    w = jnp.asarray(a, jnp.float32)
    g, w_next, r = ttd_step(w, jnp.asarray(1e-3, jnp.float32), 15)
    rr = int(r)
    assert rr <= 4
    assert np.abs(np.array(g)[:, rr:]).max() == 0.0
    assert np.abs(np.array(w_next)[rr:, :]).max() == 0.0


def test_ttd_step_respects_max_rank():
    rng = np.random.default_rng(2)
    w = _rand(rng, (30, 30))
    g, w_next, r = ttd_step(w, jnp.asarray(0.0, jnp.float32), 7)
    assert int(r) == 7


def test_delta_threshold_formula():
    w = jnp.ones((4, 4, 4), jnp.float32)
    d = float(delta_threshold(w, 0.1, 3))
    np.testing.assert_allclose(d, 0.1 / np.sqrt(2.0) * 8.0, rtol=1e-6)


# ---------------------------------------------------------------- ttd3


@given(
    n1=st.sampled_from([4, 9]),
    n2=st.sampled_from([8, 16]),
    n3=st.sampled_from([8, 16]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_ttd3_reconstruction_error_bound(n1, n2, n3, seed):
    """Oseledets: ||W - W_R||_F <= eps * ||W||_F for delta = eps/sqrt(d-1)*||W||."""
    rng = np.random.default_rng(seed)
    w = _rand(rng, (n1, n2, n3))
    eps = 0.3
    g1, g2, g3, r1, r2 = ttd3(w, eps)
    wr = tt_reconstruct([g1, g2, g3])
    assert _rel(wr, w) <= eps + 1e-3


def test_ttd3_exact_on_low_rank():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((9, 4))
    b = rng.standard_normal((4, 256))
    w = jnp.asarray((a @ b).reshape(9, 16, 16), jnp.float32)
    g1, g2, g3, r1, r2 = ttd3(w, 0.01)
    assert int(r1) == 4
    wr = tt_reconstruct([g1, g2, g3])
    assert _rel(wr, w) < 1e-3


def test_ttd3_core_shapes_and_boundary_ranks():
    w = jnp.zeros((9, 16, 16), jnp.float32).at[0, 0, 0].set(1.0)
    g1, g2, g3, r1, r2 = ttd3(w, 0.1)
    assert g1.shape[0] == 1 and g3.shape[2] == 1  # r_0 = r_N = 1
    assert g1.shape[2] == g2.shape[0]
    assert g2.shape[2] == g3.shape[0]


# ---------------------------------------------------------------- ttd4


def test_ttd4_reconstruction_error_bound():
    rng = np.random.default_rng(4)
    w = _rand(rng, (3, 3, 16, 16))
    eps = 0.35
    g1, g2, g3, g4, r1, r2, r3 = ttd4(w, eps)
    wr = tt_reconstruct([g1, g2, g3, g4])
    assert _rel(wr, w) <= eps + 1e-3


# ------------------------------------------------------ reconstruction


def test_tt_reconstruct_matches_einsum():
    rng = np.random.default_rng(5)
    g1 = _rand(rng, (1, 5, 3))
    g2 = _rand(rng, (3, 6, 4))
    g3 = _rand(rng, (4, 7, 1))
    got = tt_reconstruct([g1, g2, g3])
    want = np.einsum("aib,bjc,ckd->ijk", np.array(g1), np.array(g2), np.array(g3))
    np.testing.assert_allclose(np.array(got), want, rtol=1e-4, atol=1e-4)


def test_tt_reconstruct_two_cores():
    rng = np.random.default_rng(6)
    g1 = _rand(rng, (1, 5, 3))
    g2 = _rand(rng, (3, 8, 1))
    got = tt_reconstruct([g1, g2])
    want = np.einsum("aib,bjc->ij", np.array(g1), np.array(g2))
    np.testing.assert_allclose(np.array(got), want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ accounting


def test_compression_stats():
    tt, dense = model.compression_stats([9, 64, 64], [1, 9, 32, 1])
    assert dense == 9 * 64 * 64
    assert tt == 1 * 9 * 9 + 9 * 64 * 32 + 32 * 64 * 1


def test_conv_compress_roundtrip():
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.standard_normal((3, 3, 8, 8)), jnp.float32)
    g1, g2, g3, r1, r2 = model.ttd_compress_conv(w, 0.4, 8)
    wr = model.ttd_reconstruct_conv(g1, g2, g3, w.shape)
    assert wr.shape == w.shape
    assert _rel(wr, w) <= 0.4 + 1e-3
