"""L1 kernel correctness: Pallas vs pure-jnp oracle (the CORE signal).

Hypothesis sweeps shapes and dtypes; every kernel must agree with its
``ref.py`` oracle to dtype-appropriate tolerance.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.gemm_block import gemm, tile_count
from compile.kernels.house_update import (
    house_update_from_q,
    house_update_left,
    house_update_right,
)
from compile.kernels.norm import norm as stream_norm

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=20, derandomize=True
)
hypothesis.settings.load_profile("kernels")

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------- norm


@given(
    n=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31),
    chunk=st.sampled_from([16, 128, 1024]),
)
def test_norm_matches_ref(n, seed, chunk):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n,), jnp.float32)
    got = stream_norm(x, chunk=chunk)
    want = ref.norm(x)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-6)


def test_norm_zero_vector():
    assert float(stream_norm(jnp.zeros(37, jnp.float32))) == 0.0


def test_norm_large_magnitude_accumulates_in_f32():
    x = jnp.full((1000,), 1e3, jnp.float32)
    np.testing.assert_allclose(float(stream_norm(x)), 1e3 * np.sqrt(1000.0), rtol=1e-5)


# ------------------------------------------------------- house_update


@given(
    m=st.integers(min_value=2, max_value=300),
    n=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31),
    block=st.sampled_from([32, 128]),
)
def test_house_update_left_matches_ref(m, n, seed, block):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, n), jnp.float32)
    x = _rand(rng, (m,), jnp.float32)
    q, v = ref.house(x)
    got = house_update_left(v, a, v[0] * q, block=block)
    want = ref.house_update_left(q, v, a)
    np.testing.assert_allclose(np.array(got), np.array(want), **_tol(jnp.float32))


@given(
    m=st.integers(min_value=1, max_value=300),
    n=st.integers(min_value=2, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31),
    block=st.sampled_from([32, 128]),
)
def test_house_update_right_matches_ref(m, n, seed, block):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, n), jnp.float32)
    y = _rand(rng, (n,), jnp.float32)
    q, v = ref.house(y)
    got = house_update_right(v, a, v[0] * q, block=block)
    want = ref.house_update_right(q, v, a)
    np.testing.assert_allclose(np.array(got), np.array(want), **_tol(jnp.float32))


@pytest.mark.parametrize("order", [0, 1])
def test_house_update_from_q_is_algorithm2(order):
    """The q-based convenience reproduces HOUSE_MM_UPDATE verbatim."""
    rng = np.random.default_rng(7)
    a = _rand(rng, (64, 48), jnp.float32)
    vec = _rand(rng, (64 if order == 0 else 48,), jnp.float32)
    q, v = ref.house(vec)
    got = house_update_from_q(q, v, a, order)
    want = (ref.house_update_left if order == 0 else ref.house_update_right)(q, v, a)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-4, atol=2e-4)


def test_house_update_left_is_householder_reflection():
    """A <- update(A) must equal H @ A for H = I - 2vv^T/(v^Tv)."""
    rng = np.random.default_rng(3)
    a = _rand(rng, (40, 24), jnp.float32)
    x = _rand(rng, (40,), jnp.float32)
    q, v = ref.house(x)
    h = np.eye(40) - 2.0 * np.outer(v, v) / float(v @ v)
    got = house_update_left(v, a, v[0] * q)
    np.testing.assert_allclose(np.array(got), h @ np.array(a), rtol=1e-4, atol=1e-4)


def test_house_update_annihilates_column():
    """After the left transform the pivot column is q * e1."""
    rng = np.random.default_rng(4)
    a = _rand(rng, (32, 8), jnp.float32)
    q, v = ref.house(a[:, 0])
    out = np.array(house_update_left(v, a, v[0] * q))
    np.testing.assert_allclose(out[0, 0], float(q), rtol=1e-5)
    np.testing.assert_allclose(out[1:, 0], 0.0, atol=1e-4)


# ---------------------------------------------------------------- gemm


@given(
    m=st.integers(min_value=1, max_value=200),
    k=st.integers(min_value=1, max_value=200),
    n=st.integers(min_value=1, max_value=200),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_gemm_matches_ref_f32(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), jnp.float32)
    y = _rand(rng, (k, n), jnp.float32)
    got = gemm(x, y)
    np.testing.assert_allclose(
        np.array(got), np.array(ref.gemm(x, y)), rtol=1e-4, atol=1e-4
    )


@given(
    m=st.integers(min_value=1, max_value=96),
    k=st.integers(min_value=1, max_value=96),
    n=st.integers(min_value=1, max_value=96),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_gemm_matches_ref_bf16(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), jnp.bfloat16)
    y = _rand(rng, (k, n), jnp.bfloat16)
    got = gemm(x, y)
    np.testing.assert_allclose(
        np.array(got, np.float32),
        np.array(ref.gemm(x, y), np.float32),
        **_tol(jnp.bfloat16),
    )


@given(
    bm=st.sampled_from([32, 64, 128]),
    bk=st.sampled_from([32, 128]),
    bn=st.sampled_from([32, 128]),
)
def test_gemm_block_shape_invariance(bm, bk, bn):
    """Result must not depend on the chosen block decomposition."""
    rng = np.random.default_rng(11)
    x = _rand(rng, (150, 90), jnp.float32)
    y = _rand(rng, (90, 170), jnp.float32)
    got = gemm(x, y, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(
        np.array(got), np.array(ref.gemm(x, y)), rtol=1e-4, atol=1e-4
    )


def test_tile_count_matches_paper_pe_array():
    # 64x64 @ 64x64 on 16x16 tiles: 4*4*4 = 64 tile-ops.
    assert tile_count(64, 64, 64) == 64
    assert tile_count(1, 1, 1) == 1
    assert tile_count(17, 16, 16) == 2
