"""Strategies for the offline hypothesis shim.

Each strategy is a tiny object with ``example(rnd)`` drawing one value
from a ``random.Random``. Only the strategies the test suite uses are
implemented; ``map``/``filter``/``flatmap`` are provided because they
are cheap and keep future tests working.
"""

from __future__ import annotations

import math


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd):
        return self._draw(rnd)

    def map(self, f):
        return SearchStrategy(lambda rnd: f(self._draw(rnd)))

    def filter(self, pred, _max_tries=1000):
        def draw(rnd):
            for _ in range(_max_tries):
                v = self._draw(rnd)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")

        return SearchStrategy(draw)

    def flatmap(self, f):
        return SearchStrategy(lambda rnd: f(self._draw(rnd)).example(rnd))


def integers(min_value=None, max_value=None):
    lo = -(2**31) if min_value is None else int(min_value)
    hi = 2**31 if max_value is None else int(max_value)

    def draw(rnd):
        # Bias toward the boundaries now and then: that is where the
        # real library finds most of its counterexamples.
        r = rnd.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return rnd.randint(lo, hi)

    return SearchStrategy(draw)


def floats(min_value=None, max_value=None, allow_nan=False, allow_infinity=False, width=64):
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)

    def draw(rnd):
        v = rnd.uniform(lo, hi)
        return v if math.isfinite(v) else 0.0

    return SearchStrategy(draw)


def booleans():
    return SearchStrategy(lambda rnd: rnd.random() < 0.5)


def sampled_from(elements):
    seq = list(elements)
    if not seq:
        raise ValueError("sampled_from needs a non-empty collection")
    return SearchStrategy(lambda rnd: seq[rnd.randrange(len(seq))])


def lists(elements, min_size=0, max_size=10):
    def draw(rnd):
        n = rnd.randint(min_size, max_size)
        return [elements.example(rnd) for _ in range(n)]

    return SearchStrategy(draw)


def tuples(*strats):
    return SearchStrategy(lambda rnd: tuple(s.example(rnd) for s in strats))


def just(value):
    return SearchStrategy(lambda rnd: value)


def one_of(*strats):
    flat = []
    for s in strats:
        flat.extend(s if isinstance(s, (list, tuple)) else [s])
    return SearchStrategy(lambda rnd: flat[rnd.randrange(len(flat))].example(rnd))


def composite(f):
    def builder(*args, **kwargs):
        def draw_value(rnd):
            def draw(strategy):
                return strategy.example(rnd)

            return f(draw, *args, **kwargs)

        return SearchStrategy(draw_value)

    return builder
