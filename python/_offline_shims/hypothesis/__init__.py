"""Offline stand-in for the ``hypothesis`` property-testing library.

The CI image has no network access and no ``hypothesis`` wheel, so
``conftest.py`` puts this package on ``sys.path`` *only when the real
library is missing*. It implements the narrow API surface the test
suite uses — ``given``, ``settings`` (profiles), ``assume`` and the
``strategies`` module — with deterministic example generation: each
test draws ``max_examples`` cases from a PRNG seeded by the test's
qualified name (the moral equivalent of hypothesis' ``derandomize``
profile the suite already requests).

Failures re-raise the original assertion augmented with the drawn
arguments, which is the part of hypothesis we actually rely on:
reproducible counterexamples. Shrinking is out of scope.
"""

from __future__ import annotations

import functools
import inspect as _inspect
import random
import types as _types
import zlib

from . import strategies  # noqa: F401  (re-export: hypothesis.strategies)

__version__ = "0.0-offline-shim"

__all__ = ["given", "settings", "assume", "example", "HealthCheck", "strategies"]


class UnsatisfiedAssumption(Exception):
    """Raised by :func:`assume` to skip one drawn example."""


def assume(condition):
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class HealthCheck:
    """Placeholder namespace (profiles sometimes reference it)."""

    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"


class settings:
    """Profile registry + per-test settings decorator."""

    _profiles: dict = {"default": {"max_examples": 20, "deadline": None, "derandomize": True}}
    _current: dict = dict(_profiles["default"])

    def __init__(self, parent=None, **kwargs):
        self.kwargs = dict(kwargs)

    def __call__(self, fn):
        merged = {**getattr(fn, "_shim_settings", {}), **self.kwargs}
        fn._shim_settings = merged
        return fn

    @classmethod
    def register_profile(cls, name, parent=None, **kwargs):
        base = dict(cls._profiles.get("default", {}))
        base.update(kwargs)
        cls._profiles[name] = base

    @classmethod
    def load_profile(cls, name):
        cls._current = dict(cls._profiles.get(name, cls._profiles["default"]))

    @classmethod
    def get_profile(cls, name):
        return cls._profiles[name]


def example(*args, **kwargs):
    """Record an explicit example (prepended to the generated ones)."""

    def deco(fn):
        fn._shim_examples = getattr(fn, "_shim_examples", []) + [(args, kwargs)]
        return fn

    return deco


def given(*given_args, **given_kwargs):
    if given_args:
        raise TypeError("the offline hypothesis shim supports keyword strategies only")
    # Settings are bound at decoration time, matching hypothesis'
    # behaviour of picking up the profile the module just loaded.
    bound_settings = dict(settings._current)

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings / @example compose in either stacking order:
            # below @given they decorate fn (functools.wraps copies the
            # attrs onto this wrapper); above @given, in the canonical
            # hypothesis order, they land on the wrapper directly and
            # extend the wraps-copied values. Either way the wrapper
            # carries the complete, deduplicated set.
            opts = {**bound_settings, **getattr(wrapper, "_shim_settings", {})}
            max_examples = int(opts.get("max_examples", 20))
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rnd = random.Random(seed)
            ran = 0
            attempts = 0
            for explicit_args, explicit_kwargs in getattr(wrapper, "_shim_examples", []):
                fn(*args, *explicit_args, **kwargs, **explicit_kwargs)
            while ran < max_examples and attempts < max_examples * 50:
                attempts += 1
                drawn = {k: strat.example(rnd) for k, strat in given_kwargs.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except UnsatisfiedAssumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"property failed on example {ran} "
                        f"(seed={seed}, drawn={drawn!r}): {e}"
                    ) from e
                ran += 1
            return None

        # pytest's fixture introspection reads `obj.hypothesis.inner_test`
        # for hypothesis-wrapped tests; mirror that shape. The exposed
        # signature must also drop the strategy-supplied parameters, or
        # pytest hunts for fixtures named like them (`__wrapped__`, set
        # by functools.wraps, would otherwise resurface the originals).
        wrapper.hypothesis = _types.SimpleNamespace(inner_test=fn)
        del wrapper.__wrapped__
        sig = _inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items() if name not in given_kwargs]
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper

    return deco
