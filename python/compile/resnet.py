"""L2: ResNet-32 (CIFAR-10) in JAX -- the paper's benchmark model.

Standard He et al. CIFAR ResNet with n=5 (6n+2 = 32 layers) and
option-A shortcuts (stride-2 subsample + zero channel padding), which
keeps the parameter count at ~0.47 M exactly as Table I reports for the
uncompressed model.

BatchNorm is folded to inference form (per-channel scale + bias): the
compression study operates on *trained, frozen* parameters, matching
the paper's workflow of compressing a trained local model.

The parameter layout is a flat ordered list (see ``param_specs``) so
the AOT-exported forward pass has a deterministic PJRT argument order
that the rust runtime replays from ``artifacts/manifest.json``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NUM_CLASSES = 10
BLOCKS_PER_STAGE = 5
STAGE_CHANNELS = (16, 32, 64)


def param_specs():
    """Ordered (name, shape) list of every parameter array."""
    specs = [
        ("conv_init/w", (3, 3, 3, 16)),
        ("bn_init/scale", (16,)),
        ("bn_init/bias", (16,)),
    ]
    in_ch = 16
    for s, ch in enumerate(STAGE_CHANNELS):
        for b in range(BLOCKS_PER_STAGE):
            c_in = in_ch if b == 0 else ch
            p = f"stage{s}/block{b}"
            specs += [
                (f"{p}/conv1/w", (3, 3, c_in, ch)),
                (f"{p}/bn1/scale", (ch,)),
                (f"{p}/bn1/bias", (ch,)),
                (f"{p}/conv2/w", (3, 3, ch, ch)),
                (f"{p}/bn2/scale", (ch,)),
                (f"{p}/bn2/bias", (ch,)),
            ]
        in_ch = ch
    specs += [
        ("fc/w", (STAGE_CHANNELS[-1], NUM_CLASSES)),
        ("fc/b", (NUM_CLASSES,)),
    ]
    return specs


def conv_param_specs():
    """The conv kernels -- the tensors the paper compresses via TTD."""
    return [(n, s) for n, s in param_specs() if n.endswith("conv1/w") or n.endswith("conv2/w") or n == "conv_init/w"]


def param_count() -> int:
    import math

    return sum(math.prod(s) for _, s in param_specs())


def init_params(key):
    """He-normal initialized flat parameter list."""
    params = []
    for name, shape in param_specs():
        key, sub = jax.random.split(key)
        if name.endswith("/w") and len(shape) == 4:
            fan_in = shape[0] * shape[1] * shape[2]
            p = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)
        elif name.endswith("fc/w"):
            p = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(1.0 / shape[0])
        elif name.endswith("bn2/scale"):
            # Zero-init the last BN scale of each residual block: blocks
            # start as identity, keeping folded-BN activations bounded
            # through all 32 layers (no running-stat normalization here).
            p = jnp.zeros(shape, jnp.float32)
        elif name.endswith("/scale"):
            p = jnp.ones(shape, jnp.float32)
        else:
            p = jnp.zeros(shape, jnp.float32)
        params.append(p)
    return params


def _conv(x, w, stride=1):
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _bn(x, scale, bias):
    return x * scale[None, None, None, :] + bias[None, None, None, :]


def _shortcut_a(x, out_ch: int, stride: int):
    """Option-A shortcut: subsample + zero-pad channels (no params)."""
    if stride > 1:
        x = x[:, ::stride, ::stride, :]
    pad = out_ch - x.shape[-1]
    if pad > 0:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad)))
    return x


def forward(params, x):
    """ResNet-32 inference: x (B, 32, 32, 3) -> logits (B, 10)."""
    it = iter(params)
    nxt = lambda: next(it)  # noqa: E731

    h = _bn(_conv(x, nxt()), nxt(), nxt())
    h = jax.nn.relu(h)

    in_ch = 16
    for s, ch in enumerate(STAGE_CHANNELS):
        for b in range(BLOCKS_PER_STAGE):
            stride = 2 if (s > 0 and b == 0) else 1
            y = _bn(_conv(h, nxt(), stride), nxt(), nxt())
            y = jax.nn.relu(y)
            y = _bn(_conv(y, nxt()), nxt(), nxt())
            h = jax.nn.relu(y + _shortcut_a(h, ch, stride))
        in_ch = ch

    h = jnp.mean(h, axis=(1, 2))  # global average pool
    return h @ nxt() + nxt()


def loss_fn(params, x, labels):
    """Softmax cross-entropy -- used by the tiny-corpus training run."""
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def sgd_step(params, x, labels, lr: float, clip: float = 1.0):
    """One SGD step with global-norm gradient clipping.

    Clipping keeps large learning rates stable (the folded-BN model
    has no activation normalization); exported for the e2e
    federated-training example.
    """
    loss, grads = jax.value_and_grad(loss_fn)(params, x, labels)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
    scale = jnp.minimum(1.0, clip / (gnorm + 1e-12))
    new_params = [p - lr * scale * g for p, g in zip(params, grads)]
    return new_params, loss
