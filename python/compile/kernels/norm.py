"""Streaming vector-norm Pallas kernel -- the Shared FP-ALU ``norm`` op.

The paper's FP-ALU Vector Streamer reads SPM elements into a FIFO while
the FP-ALU CORE squares-and-accumulates via MAC, applying one final
SQRT (section III-C).  The Pallas equivalent is a single-pass chunked
reduction: each grid step MACs one block into a scalar accumulator held
in SMEM-like scratch; the last step applies SQRT.  No intermediate
vector is ever materialized -- the same property that lets the hardware
version run at 1 element/cycle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 1024


def _norm_kernel(x_ref, o_ref, acc_ref, *, n_chunks: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[0] = jnp.float32(0.0)

    x = x_ref[...].astype(jnp.float32)
    acc_ref[0] += jnp.sum(x * x)  # MAC stream over this chunk

    @pl.when(i == n_chunks - 1)
    def _fini():
        o_ref[0] = jnp.sqrt(acc_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk",))
def norm(x, *, chunk: int = DEFAULT_CHUNK):
    """``sqrt(sum(x_i^2))`` over a 1-D vector, single streaming pass."""
    (n,) = x.shape
    c = min(chunk, n)
    pad = (-n) % c
    if pad:  # zero tail is a no-op for a sum of squares
        x = jnp.pad(x, (0, pad))
    n_chunks = pl.cdiv(n + pad, c)
    return pl.pallas_call(
        functools.partial(_norm_kernel, n_chunks=n_chunks),
        grid=(n_chunks,),
        in_specs=[pl.BlockSpec((c,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=True,
    )(x)[0]
