"""Fused HOUSE_MM_UPDATE Pallas kernels (Algorithm 2, lines 27-32).

The paper's HBD-ACC issues *two consecutive GEMMs* per Householder
update (``v^T x SubArray`` then the rank-1 outer product), keeping the
Householder vector resident in the GEMM accelerator's SPM between the
two.  The TPU analogue (DESIGN.md section 8) keeps ``v`` and ``v/beta``
VMEM-resident across both contractions and streams each block of ``A``
through VMEM exactly once per update:

  left  (order=0):  A <- A + outer(v / beta, v^T A)
  right (order=1):  A <- A + outer(A v,      v / beta)

with ``beta = v1 * q`` computed by the VEC-DIVISION stage (v1 is the
pivot element of ``v``).  ``beta`` is an explicit operand here because
the L2 model runs HBD in masked fixed-shape form, where the pivot sits
at a dynamic row/column index rather than at ``v[0]``.

Grid layout:
  * left:  one program per *column* block; the block sees all M rows, so
    ``w = v @ A_blk`` and the outer-product update complete locally.
  * right: one program per *row* block; symmetric.

This is a single HBM pass over ``A`` versus three for the unfused
sequence (read for w, read+write for the update), which is exactly the
traffic the paper eliminates with SPM retention.

All kernels run with ``interpret=True`` (CPU correctness path); real-TPU
efficiency is estimated analytically in DESIGN.md / EXPERIMENTS.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Column/row block width. 128 matches the TPU lane width; the paper's
# 16x16 accelerator tiles sub-divide it exactly (DESIGN.md section 8).
DEFAULT_BLOCK = 128


def _left_kernel(v_ref, beta_ref, a_ref, o_ref):
    """One column-block of ``A + outer(v/beta, v @ A)``."""
    v = v_ref[...]  # (M,) -- VMEM-resident across both contractions
    a = a_ref[...]  # (M, bn)
    beta = beta_ref[0]
    w = v @ a  # first "GEMM": (bn,)
    # second "GEMM": rank-1 update, fused -- A is still in VMEM.
    o_ref[...] = a + (v / beta)[:, None] * w[None, :]


def _right_kernel(v_ref, beta_ref, a_ref, o_ref):
    """One row-block of ``A + outer(A @ v, v/beta)``."""
    v = v_ref[...]  # (N,)
    a = a_ref[...]  # (bm, N)
    beta = beta_ref[0]
    u = a @ v  # (bm,)
    o_ref[...] = a + u[:, None] * (v / beta)[None, :]


@functools.partial(jax.jit, static_argnames=("block",))
def house_update_left(v, a, beta, *, block: int = DEFAULT_BLOCK):
    """``A + (v/beta)(v^T A)``.  v: (M,), a: (M, N), beta: scalar."""
    m, n = a.shape
    bn = min(block, n)
    pad = (-n) % bn
    if pad:  # zero column padding: w and the update are zero there
        a = jnp.pad(a, ((0, 0), (0, pad)))
    grid = (pl.cdiv(n + pad, bn),)
    beta = jnp.asarray(beta, a.dtype).reshape(1)
    out = pl.pallas_call(
        _left_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m,), lambda j: (0,)),          # v: broadcast
            pl.BlockSpec((1,), lambda j: (0,)),          # beta
            pl.BlockSpec((m, bn), lambda j: (0, j)),     # A column block
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n + pad), a.dtype),
        interpret=True,
    )(v, beta, a)
    return out[:, :n] if pad else out


@functools.partial(jax.jit, static_argnames=("block",))
def house_update_right(v, a, beta, *, block: int = DEFAULT_BLOCK):
    """``A + (A v)(v/beta)``.  v: (N,), a: (M, N), beta: scalar."""
    m, n = a.shape
    bm = min(block, m)
    pad = (-m) % bm
    if pad:  # zero row padding: u and the update are zero there
        a = jnp.pad(a, ((0, pad), (0, 0)))
    grid = (pl.cdiv(m + pad, bm),)
    beta = jnp.asarray(beta, a.dtype).reshape(1)
    out = pl.pallas_call(
        _right_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),          # v: broadcast
            pl.BlockSpec((1,), lambda i: (0,)),          # beta
            pl.BlockSpec((bm, n), lambda i: (i, 0)),     # A row block
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m + pad, n), a.dtype),
        interpret=True,
    )(v, beta, a)
    return out[:m, :] if pad else out


def house_update_from_q(q, v, a, order: int, *, block: int = DEFAULT_BLOCK):
    """HOUSE_MM_UPDATE exactly as Algorithm 2 writes it: beta = v[0]*q.

    Standalone (unmasked) convenience used by pytest to check the kernel
    against the Algorithm-2 oracle in :mod:`ref`.
    """
    beta = v[0] * q
    if order == 0:
        return house_update_left(v, a, beta, block=block)
    return house_update_right(v, a, beta, block=block)
