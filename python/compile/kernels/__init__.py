"""L1: Pallas kernels for the TT-Edge compute hot-spot.

Modules:
  * :mod:`house_update` -- fused HOUSE_MM_UPDATE (Algorithm 2) rank-1 update
  * :mod:`gemm_block`   -- blocked GEMM mirroring the 16x16 accelerator
  * :mod:`norm`         -- streaming vector norm (Shared FP-ALU opcode)
  * :mod:`ref`          -- pure-jnp oracles for all of the above
"""

from . import gemm_block, house_update, norm, ref  # noqa: F401
