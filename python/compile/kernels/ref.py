"""Pure-jnp reference oracles for the TT-Edge Pallas kernels.

Every Pallas kernel in this package has an exact (up to float
reassociation) counterpart here; pytest asserts allclose between the
two.  These functions are also the executable specification of the
paper's Algorithm 2 primitives:

  * ``house``            -- HOUSE(x): Householder vector + q   (Alg. 2, l. 22-26)
  * ``house_update_*``   -- HOUSE_MM_UPDATE(q, v, A, order)    (Alg. 2, l. 27-32)
  * ``gemm``             -- the GEMM accelerator's matmul
  * ``norm``             -- the Shared FP-ALU's streaming norm opcode
"""

from __future__ import annotations

import jax.numpy as jnp


def norm(x: jnp.ndarray) -> jnp.ndarray:
    """Shared FP-ALU ``norm``: sqrt(sum(x_i^2)) via MAC stream + SQRT."""
    x = x.reshape(-1)
    return jnp.sqrt(jnp.sum(x * x))


def house(x: jnp.ndarray):
    """HOUSE(x) from Algorithm 2.

    Returns ``(q, v)`` with ``q = -sign(x1) * ||x||`` and
    ``v = x + sign(x1) * ||x|| * e1``.  ``sign`` follows the hardware
    convention ``sign(0) = +1`` (the FP-ALU reads the IEEE sign bit).
    """
    nrm = norm(x)
    s = jnp.where(jnp.signbit(x[0]), -1.0, 1.0).astype(x.dtype)
    q = -s * nrm
    v = x.at[0].add(s * nrm)
    return q, v


def house_update_left(q: jnp.ndarray, v: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """HOUSE_MM_UPDATE with order=0 (left transform).

    ``A <- A + (v / beta) (v^T A)`` with ``beta = v[0] * q``.  This equals
    ``H A`` for ``H = I - 2 v v^T / (v^T v)`` because
    ``v^T v = -2 q v[0] = -2 beta`` for a HOUSE-generated ``v``.
    """
    beta = v[0] * q
    w = v @ a  # (n,)
    return a + jnp.outer(v / beta, w)


def house_update_right(q: jnp.ndarray, v: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """HOUSE_MM_UPDATE with order=1 (right transform).

    ``A <- A + (A v^T) (v / beta)`` with ``beta = v[0] * q`` -- i.e. ``A H``.
    """
    beta = v[0] * q
    u = a @ v  # (m,)
    return a + jnp.outer(u, v / beta)


def gemm(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Reference matmul for the blocked GEMM kernel."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def hbd_reference(a: jnp.ndarray):
    """Straight-line Householder bidiagonalization (Golub & Van Loan 5.4.3).

    Dense, numpy-style loop over shrinking submatrices -- the oracle the
    masked fixed-shape L2 implementation is tested against.
    Returns ``(U_B, B, V_B^T)`` with ``A = U_B @ B @ V_B^T``.
    """
    m, n = a.shape
    assert m >= n, "HBD oracle expects a tall (M >= N) matrix"
    a = a.astype(jnp.float32)
    u = jnp.eye(m, dtype=jnp.float32)
    vt = jnp.eye(n, dtype=jnp.float32)
    for i in range(n):
        # Left transform: zero sub-diagonal of column i.
        x = a[i:, i]
        _, v = house(x)
        h = jnp.eye(m - i) - 2.0 * jnp.outer(v, v) / (v @ v)
        a = a.at[i:, i:].set(h @ a[i:, i:])
        u = u.at[:, i:].set(u[:, i:] @ h)
        if i < n - 2:
            # Right transform: zero row i beyond the superdiagonal.
            y = a[i, i + 1:]
            _, v = house(y)
            h = jnp.eye(n - i - 1) - 2.0 * jnp.outer(v, v) / (v @ v)
            a = a.at[i:, i + 1:].set(a[i:, i + 1:] @ h)
            vt = vt.at[i + 1:, :].set(h @ vt[i + 1:, :])
    b = jnp.triu(jnp.tril(a[:n, :n], 1))  # keep main + first super diagonal
    return u, b, vt
