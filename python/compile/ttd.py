"""L2: Tensor-Train Decomposition (Algorithm 1) and TT reconstruction.

Fixed-shape, padded-rank formulation so every step AOT-exports:

* At step ``k`` the working matrix has ``r_{k-1} n_k`` rows and
  ``prod_{j>k} n_j`` columns -- the column count is rank-independent, so
  padding the rank dimension with zero rows keeps every shape static.
  Zero rows only contribute zero singular values, which the
  delta-truncation discards anyway; the padded pipeline is therefore
  *exactly* the truncated pipeline plus zero blocks.

* ``delta``-truncation (Alg. 1, l. 27-31) emits a rank ``r`` plus a
  column mask; cores stay padded, consumers slice to ``r`` (the rust
  coordinator does, for wire-size accounting).

Reconstruction follows Eq. (1)/(2): chained reshape+matmul, executed on
the blocked-GEMM Pallas kernel -- the same unit the paper reuses.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.gemm_block import gemm
from .svd import svd


def delta_threshold(w, eps: float, d: int):
    """``delta = eps / sqrt(d-1) * ||W||_F`` (Alg. 1, l. 5)."""
    return eps / jnp.sqrt(jnp.asarray(d - 1.0, jnp.float32)) * jnp.sqrt(
        jnp.sum(w.astype(jnp.float32) ** 2)
    )


def ttd_step(w_mat, delta, max_rank: int, *, sweeps: int = 12):
    """One Algorithm-1 iteration on the working matrix.

    SVD -> (already sorted) -> delta-truncation -> split.

    Returns ``(g, w_next, r)``:
      * ``g``      (m, kmax): truncated-U, columns >= r zeroed
      * ``w_next`` (kmax, n): ``Sigma_t V_t^T``, rows >= r zeroed
      * ``r``      (): int32 retained rank, 1 <= r <= max_rank
    where ``kmax = min(m, n)`` (static).  Consumers slice to ``r``.
    """
    m, n = w_mat.shape
    kmax = min(m, n)
    u, s, vt = svd(w_mat, sweeps=sweeps)

    # delta-truncation: keep the smallest prefix whose discarded tail
    # has Frobenius norm < delta.  tail[i] = ||s[i:]||_F ; keep i while
    # tail[i] >= delta.
    tail = jnp.sqrt(jnp.cumsum((s * s)[::-1])[::-1])
    r = jnp.sum((tail >= delta).astype(jnp.int32))
    r = jnp.clip(r, 1, max_rank)

    mask = (jnp.arange(kmax) < r).astype(jnp.float32)
    g = u * mask[None, :]
    w_next = (s * mask)[:, None] * vt
    return g, w_next, r


def ttd3(w, eps: float, max_ranks=(None, None), *, sweeps: int = 12):
    """TTD of a 3-D tensor ``w`` (n1, n2, n3) into padded cores.

    Returns ``(g1, g2, g3, r1, r2)``:
      * ``g1`` (1, n1, k1)   * ``g2`` (k1, n2, k2)   * ``g3`` (k2, n3, 1)
    with ``k1 = min(n1, n2*n3)`` and ``k2 = min(k1*n2, n3)`` (static),
    entries beyond (r1, r2) exactly zero.
    """
    n1, n2, n3 = w.shape
    d = 3
    delta = delta_threshold(w, eps, d)
    r1_cap = max_ranks[0] or min(n1, n2 * n3)
    r2_cap = max_ranks[1] or n3

    w1 = w.reshape(n1, n2 * n3)
    g1, w2, r1 = ttd_step(w1, delta, r1_cap, sweeps=sweeps)
    k1 = g1.shape[1]

    w2 = w2.reshape(k1 * n2, n3)
    g2, w3, r2 = ttd_step(w2, delta, r2_cap, sweeps=sweeps)
    k2 = g2.shape[1]

    return (
        g1.reshape(1, n1, k1),
        g2.reshape(k1, n2, k2),
        w3.reshape(k2, n3, 1),
        r1,
        r2,
    )


def ttd4(w, eps: float, max_ranks=(None, None, None), *, sweeps: int = 12):
    """TTD of a 4-D tensor ``w`` (n1, n2, n3, n4) into 4 padded cores."""
    n1, n2, n3, n4 = w.shape
    delta = delta_threshold(w, eps, 4)
    caps = [
        max_ranks[0] or min(n1, n2 * n3 * n4),
        max_ranks[1] or min(n1 * n2, n3 * n4),
        max_ranks[2] or n4,
    ]

    w1 = w.reshape(n1, n2 * n3 * n4)
    g1, w2, r1 = ttd_step(w1, delta, caps[0], sweeps=sweeps)
    k1 = g1.shape[1]

    w2 = w2.reshape(k1 * n2, n3 * n4)
    g2, w3, r2 = ttd_step(w2, delta, caps[1], sweeps=sweeps)
    k2 = g2.shape[1]

    w3 = w3.reshape(k2 * n3, n4)
    g3, w4, r3 = ttd_step(w3, delta, caps[2], sweeps=sweeps)
    k3 = g3.shape[1]

    return (
        g1.reshape(1, n1, k1),
        g2.reshape(k1, n2, k2),
        g3.reshape(k2, n3, k3),
        w4.reshape(k3, n4, 1),
        r1,
        r2,
        r3,
    )


def tt_reconstruct(cores):
    """Eq. (1)/(2): ``W_R = G_1 x1 G_2 x1 ... x1 G_N``.

    Each contraction is ``reshape . matmul . reshape`` on the blocked
    GEMM kernel (the reused accelerator path).  ``cores``: list of
    (r_{k-1}, n_k, r_k) arrays; returns the (n_1, ..., n_N) tensor.
    """
    acc = cores[0]  # (1, n1, k1)
    dims = [acc.shape[1]]
    for core in cores[1:]:
        rk, nk, rk1 = core.shape
        left = acc.reshape(-1, rk)  # ([n1..n_{k-1}], r_{k-1}) row-major
        right = core.reshape(rk, nk * rk1)
        acc = gemm(left, right)  # ([n1..n_{k-1}], n_k * r_k) -- stays flat
        dims.append(nk)
    assert cores[-1].shape[2] == 1, "last core must have r_N = 1"
    return acc.reshape(*dims)
