"""L2 assembly: the TT-Edge compute graph, built on the L1 kernels.

This module is the single import surface the AOT exporter and the
pytest suite use.  It stitches together:

  * :mod:`svd`     -- HBD (Pallas ``house_update``/``norm``) + Jacobi
  * :mod:`ttd`     -- Algorithm 1 on padded fixed shapes + Eq. (1)/(2)
  * :mod:`resnet`  -- ResNet-32, the compression workload
  * :mod:`kernels` -- the raw L1 entry points (exported standalone too)

Everything lowers to static-shape HLO; ``aot.py`` writes one artifact
per entry point plus ``manifest.json`` describing PJRT argument order.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import resnet, ttd
from .kernels import gemm_block, house_update, norm  # noqa: F401
from .svd import hbd, jacobi_svd, svd  # noqa: F401
from .ttd import delta_threshold, tt_reconstruct, ttd3, ttd4, ttd_step  # noqa: F401


def ttd_compress_conv(w, eps: float, max_rank: int, *, sweeps: int = 12):
    """Compress one (kh, kw, cin, cout) conv kernel as a 3-D TT.

    The paper reshapes conv weights before decomposition (Alg. 1 l. 7);
    we use the (kh*kw, cin, cout) factorization -- the layout TIE/ETTE
    use for conv layers -- giving three cores.
    """
    kh, kw, cin, cout = w.shape
    t = w.reshape(kh * kw, cin, cout)
    return ttd3(t, eps, (min(max_rank, kh * kw), min(max_rank, cout)), sweeps=sweeps)


def ttd_reconstruct_conv(g1, g2, g3, shape):
    """Inverse of :func:`ttd_compress_conv`."""
    t = tt_reconstruct([g1, g2, g3])
    return t.reshape(shape)


def resnet32_forward(params, x):
    """Alias re-exported for the AOT manifest."""
    return resnet.forward(params, x)


def compression_stats(dims, ranks):
    """(#params TT, #params dense) for a TT with ``dims``/``ranks``.

    ``ranks`` includes the r_0 = r_N = 1 boundary: len(ranks) = len(dims)+1.
    Used by pytest to cross-check the rust-side accounting in
    ``rust/src/ttd/ttd.rs``.
    """
    dense = 1
    for n in dims:
        dense *= n
    tt = sum(int(ranks[i]) * dims[i] * int(ranks[i + 1]) for i in range(len(dims)))
    return tt, dense
