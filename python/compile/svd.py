"""L2: SVD via Householder bidiagonalization + one-sided Jacobi.

This is the paper's SVD split (section II-A-2): *bidiagonalization*
(the HBD-ACC's job, built on the L1 ``house_update`` Pallas kernel) and
*diagonalization* of the bidiagonal matrix.

Everything here is **fixed-shape**: the algorithmic loops run masked
over full-size matrices so the whole pipeline AOT-exports to a single
static HLO module (``aot.py``).  The pivot of each Householder vector
therefore sits at a *dynamic* index ``i`` instead of position 0, which
is why the L1 kernels take ``beta`` explicitly.

The paper diagonalizes B with "a standard QR-based procedure"; we use
fixed-sweep one-sided Jacobi, which is QR-iteration-class numerically
but has a static control structure (no convergence-dependent shapes),
making it exportable.  The rust substrate (rust/src/ttd/svd/) carries
the classic Golub-Kahan implicit-shift QR for the dynamic-shape path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.house_update import house_update_left, house_update_right
from .kernels.norm import norm as stream_norm

_TINY = 1e-30


def _house_masked(x, piv):
    """HOUSE (Alg. 2, l. 22-26) on the masked tail ``x[piv:]``.

    ``x`` is a full-length vector whose entries below ``piv`` are
    ignored.  Returns ``(q, v, beta)`` where ``v`` is full-length with
    zeros outside ``[piv, len)``, and ``beta = v[piv] * q``.  When the
    tail is (numerically) zero the transform degenerates to the
    identity: ``v = 0, beta = 1, q = 0``.
    """
    (ln,) = x.shape
    idx = jnp.arange(ln)
    xm = jnp.where(idx >= piv, x, 0.0)
    nrm = stream_norm(xm)
    x1 = xm[piv]
    s = jnp.where(jnp.signbit(x1), -1.0, 1.0).astype(x.dtype)
    q = -s * nrm
    degenerate = nrm <= _TINY
    v = xm.at[piv].add(s * nrm)
    v = jnp.where(degenerate, jnp.zeros_like(v), v)
    beta = jnp.where(degenerate, 1.0, v[piv] * q)
    q = jnp.where(degenerate, 0.0, q)
    return q, v, beta


@functools.partial(jax.jit, static_argnames=())
def hbd(a):
    """Householder bidiagonalization of a tall matrix (Algorithm 2).

    ``a``: (M, N) with M >= N.  Returns ``(U_B, B, V_B^T)`` with
    ``A = U_B @ B @ V_B^T``; ``U_B`` is (M, N) with orthonormal columns,
    ``B`` (N, N) upper bidiagonal, ``V_B^T`` (N, N) orthogonal.

    Phase 1 (*Householder Reduction*, Alg. 2 l. 4-13) runs a masked
    fixed-shape loop calling the fused L1 kernel once per transform;
    phase 2 (*Householder Accumulation*, l. 14-18) replays the stored
    vectors backwards over identity matrices.  The vector store ``VL`` /
    ``VR`` is the software analogue of the paper's on-chip (SPM)
    retention of Householder vectors.
    """
    m, n = a.shape
    assert m >= n, f"hbd expects tall input, got {a.shape}"
    a = a.astype(jnp.float32)
    rows = jnp.arange(m)
    cols = jnp.arange(n)

    def reduce_step(i, state):
        a, vl, bl, vr, br = state
        # -- left transform: eliminate sub-diagonal of column i.
        x = lax.dynamic_index_in_dim(a, i, axis=1, keepdims=False)
        q, v, beta = _house_masked(x, i)
        a = house_update_left(v, a, beta)
        # Exact cleanup of column i (the hardware writes B[i,i]=q and
        # never re-reads the eliminated entries).  q == 0 marks the
        # degenerate (identity) transform: leave the column untouched.
        col = jnp.where(rows > i, 0.0, jnp.where(rows == i, q, x))
        a = a.at[:, i].set(jnp.where(q == 0.0, x, col))
        vl = vl.at[i].set(v)
        bl = bl.at[i].set(beta)

        # -- right transform: eliminate row i beyond the superdiagonal.
        do_right = i < n - 2
        y = lax.dynamic_index_in_dim(a, i, axis=0, keepdims=False)
        qr_, vr_i, br_i = _house_masked(y, i + 1)
        vr_i = jnp.where(do_right, vr_i, jnp.zeros_like(vr_i))
        br_i = jnp.where(do_right, br_i, 1.0)
        a = house_update_right(vr_i, a, br_i)
        row = jnp.where(
            cols > i + 1, 0.0, jnp.where(cols == i + 1, qr_, y)
        )
        a = a.at[i, :].set(jnp.where(do_right & (qr_ != 0.0), row, y))
        vr = vr.at[i].set(vr_i)
        br = br.at[i].set(br_i)
        return a, vl, bl, vr, br

    vl0 = jnp.zeros((n, m), jnp.float32)
    bl0 = jnp.ones((n,), jnp.float32)
    vr0 = jnp.zeros((n, n), jnp.float32)
    br0 = jnp.ones((n,), jnp.float32)
    a_fin, vl, bl, vr, br = lax.fori_loop(
        0, n, reduce_step, (a, vl0, bl0, vr0, br0)
    )

    b = jnp.triu(jnp.tril(a_fin[:n, :n], 1))

    # Householder Accumulation (backward replay): U_B = H^L_1..H^L_N I,
    # V_B^T = I H^R_{N}..H^R_1  (H symmetric involutions).
    def accum_step(j, state):
        u, vt = state
        i = n - 1 - j
        u = house_update_left(vl[i], u, bl[i])
        vt = house_update_right(vr[i], vt, br[i])
        return u, vt

    u0 = jnp.eye(m, n, dtype=jnp.float32)
    vt0 = jnp.eye(n, dtype=jnp.float32)
    u, vt = lax.fori_loop(0, n, accum_step, (u0, vt0))
    return u, b, vt


@functools.partial(jax.jit, static_argnames=("sweeps",))
def jacobi_svd(b, *, sweeps: int = 12):
    """One-sided Jacobi SVD of a square matrix (the *diagonalization*).

    Fixed ``sweeps`` cyclic sweeps of Givens rotations orthogonalize the
    columns of ``G = B``; then ``sigma_k = ||G[:,k]||``, ``U = G Sigma^-1``
    and ``B = U Sigma V^T``.

    The pair order is generated by *nested fori loops with arithmetic
    indices*, NOT by gathering (p, q) from precomputed index arrays:
    the published ``xla`` crate's xla_extension 0.5.1 miscompiles the
    double constant-array gather inside a while loop (verified by the
    dbg_va/dbg_vb probes -- see DESIGN.md "AOT gotchas"), silently
    skipping rotations. Nested loops lower to plain while ops and
    round-trip correctly.

    Returns ``(U, sigma, V^T)`` with ``sigma`` sorted descending -- the
    sort *is* the paper's Sorting_Basis phase (bubble sort in hardware;
    the comparison network is order-equivalent).
    """
    n = b.shape[0]
    assert b.shape == (n, n)

    def rotate_pair(g, v, p, q):
        gp = lax.dynamic_index_in_dim(g, p, axis=1, keepdims=False)
        gq = lax.dynamic_index_in_dim(g, q, axis=1, keepdims=False)
        app = gp @ gp
        aqq = gq @ gq
        apq = gp @ gq
        # Givens rotation zeroing the (p,q) Gram entry.
        rotate = jnp.abs(apq) > 1e-12 * jnp.sqrt(app * aqq + _TINY)
        tau = (aqq - app) / (2.0 * jnp.where(rotate, apq, 1.0))
        t = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
        c = 1.0 / jnp.sqrt(1.0 + t * t)
        s = c * t
        c = jnp.where(rotate, c, 1.0)
        s = jnp.where(rotate, s, 0.0)
        g = g.at[:, p].set(c * gp - s * gq).at[:, q].set(s * gp + c * gq)
        vp = lax.dynamic_index_in_dim(v, p, axis=1, keepdims=False)
        vq = lax.dynamic_index_in_dim(v, q, axis=1, keepdims=False)
        v = v.at[:, p].set(c * vp - s * vq).at[:, q].set(s * vp + c * vq)
        return g, v

    def sweep(_, state):
        def p_loop(p, state):
            def q_loop(q, state):
                g, v = state
                return rotate_pair(g, v, p, q)

            return lax.fori_loop(p + 1, n, q_loop, state)

        return lax.fori_loop(0, n - 1, p_loop, state)

    g0 = b.astype(jnp.float32)
    v0 = jnp.eye(n, dtype=jnp.float32)
    g, v = lax.fori_loop(0, sweeps, sweep, (g0, v0))

    sigma = jnp.sqrt(jnp.sum(g * g, axis=0))
    order = jnp.argsort(-sigma)
    sigma = sigma[order]
    g = g[:, order]
    v = v[:, order]
    u = g / jnp.maximum(sigma, _TINY)[None, :]
    return u, sigma, v.T


def svd_tall(a, *, sweeps: int = 12):
    """Full SVD of a tall (M >= N) matrix: HBD then Jacobi on B."""
    u_b, b, v_bt = hbd(a)
    u_j, sigma, v_jt = jacobi_svd(b, sweeps=sweeps)
    return u_b @ u_j, sigma, v_jt @ v_bt


def svd(a, *, sweeps: int = 12):
    """Economy SVD of an arbitrary (M, N) matrix.

    Wide inputs are handled through the transpose (the shape split is
    static, so each exported module contains exactly one branch).
    """
    m, n = a.shape
    if m >= n:
        return svd_tall(a, sweeps=sweeps)
    u2, sigma, v2t = svd_tall(a.T, sweeps=sweeps)
    return v2t.T, sigma, u2.T
