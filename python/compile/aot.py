"""AOT exporter: lower L2 entry points to HLO *text* artifacts.

HLO text (NOT ``lowered.compiler_ir("hlo").as_hlo_text()`` via
``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the published ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Every entry point is lowered with ``return_tuple=True``; the rust side
unwraps with ``to_tuple()``.  ``manifest.json`` records the flattened
PJRT argument order (name/shape/dtype per input and output) so the
rust runtime (rust/src/runtime/) can marshal literals mechanically.

Run once via ``make artifacts``; python never touches the request path.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, resnet

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(x):
    return {"shape": list(x.shape), "dtype": x.dtype.name}


def _flat_specs(tree):
    return [_spec(x) for x in jax.tree_util.tree_leaves(tree)]


# --------------------------------------------------------------------------
# Entry-point registry.  Each builder returns (fn, example_args, note).
# Shapes are chosen to match the dominant ResNet-32 stage-3 conv layer
# (3x3x64x64) and the L1 kernel tile sizes -- see DESIGN.md section 4.
# --------------------------------------------------------------------------


def _entry_house_left():
    v = jnp.zeros((128,), F32)
    a = jnp.zeros((128, 128), F32)
    beta = jnp.zeros((), F32)
    fn = lambda v, a, beta: (model.house_update.house_update_left(v, a, beta),)  # noqa: E731
    return fn, (v, a, beta), "fused HOUSE_MM_UPDATE order=0, 128x128"


def _entry_house_right():
    v = jnp.zeros((128,), F32)
    a = jnp.zeros((128, 128), F32)
    beta = jnp.zeros((), F32)
    fn = lambda v, a, beta: (model.house_update.house_update_right(v, a, beta),)  # noqa: E731
    return fn, (v, a, beta), "fused HOUSE_MM_UPDATE order=1, 128x128"


def _entry_gemm():
    x = jnp.zeros((256, 256), F32)
    y = jnp.zeros((256, 256), F32)
    fn = lambda x, y: (model.gemm_block.gemm(x, y),)  # noqa: E731
    return fn, (x, y), "blocked GEMM 256x256x256 (16x16-tile schedule)"


def _entry_norm():
    x = jnp.zeros((4096,), F32)
    fn = lambda x: (model.norm.norm(x),)  # noqa: E731
    return fn, (x,), "streaming FP-ALU norm, 4096 elements"


def _entry_svd_144x64():
    a = jnp.zeros((144, 64), F32)
    fn = lambda a: model.svd(a)  # noqa: E731
    return fn, (a,), "HBD + Jacobi SVD of a (144, 64) working matrix"


def _entry_ttd3_conv64():
    w = jnp.zeros((3, 3, 64, 64), F32)
    eps = jnp.zeros((), F32)  # eps is a runtime input (traced scalar)

    def fn(w, eps):
        t = w.reshape(9, 64, 64)
        return _ttd3_traced(t, eps)

    return fn, (w, eps), "full TTD of a 3x3x64x64 conv kernel, rank cap 32"


def _ttd3_traced(t, eps):
    """ttd3 with a *traced* eps (delta computed inside the graph)."""
    from .ttd import delta_threshold, ttd_step

    n1, n2, n3 = t.shape
    delta = eps / jnp.sqrt(jnp.asarray(2.0, F32)) * jnp.sqrt(jnp.sum(t.astype(F32) ** 2))
    w1 = t.reshape(n1, n2 * n3)
    g1, w2, r1 = ttd_step(w1, delta, min(32, n1))
    k1 = g1.shape[1]
    w2 = w2.reshape(k1 * n2, n3)
    g2, w3, r2 = ttd_step(w2, delta, min(32, n3))
    k2 = g2.shape[1]
    return (
        g1.reshape(1, n1, k1),
        g2.reshape(k1, n2, k2),
        w3.reshape(k2, n3, 1),
        r1,
        r2,
    )


def _entry_tt_rec3_conv64():
    g1 = jnp.zeros((1, 9, 9), F32)
    g2 = jnp.zeros((9, 64, 64), F32)
    g3 = jnp.zeros((64, 64, 1), F32)
    fn = lambda g1, g2, g3: (model.tt_reconstruct([g1, g2, g3]),)  # noqa: E731
    return fn, (g1, g2, g3), "TT reconstruction of the 3x3x64x64 conv cores"


def _entry_resnet32_fwd():
    params = resnet.init_params(jax.random.PRNGKey(0))
    x = jnp.zeros((4, 32, 32, 3), F32)
    fn = lambda params, x: (resnet.forward(params, x),)  # noqa: E731
    return fn, (params, x), "ResNet-32 inference, batch 4, NHWC"


def _entry_resnet32_sgd():
    params = resnet.init_params(jax.random.PRNGKey(0))
    x = jnp.zeros((8, 32, 32, 3), F32)
    labels = jnp.zeros((8,), jnp.int32)
    lr = jnp.zeros((), F32)

    def fn(params, x, labels, lr):
        new_params, loss = resnet.sgd_step(params, x, labels, lr)
        return tuple(new_params) + (loss,)

    return fn, (params, x, labels, lr), "one SGD step (fwd+bwd), batch 8"


ENTRIES = {
    "house_left_128": _entry_house_left,
    "house_right_128": _entry_house_right,
    "gemm_256": _entry_gemm,
    "norm_4096": _entry_norm,
    "svd_144x64": _entry_svd_144x64,
    "ttd3_conv64": _entry_ttd3_conv64,
    "tt_rec3_conv64": _entry_tt_rec3_conv64,
    "resnet32_fwd_b4": _entry_resnet32_fwd,
    "resnet32_sgd_b8": _entry_resnet32_sgd,
}


def export_entry(name: str, out_dir: pathlib.Path) -> dict:
    fn, args, note = ENTRIES[name]()
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    (out_dir / fname).write_text(text)
    outs = jax.eval_shape(fn, *args)
    entry = {
        "name": name,
        "file": fname,
        "note": note,
        "inputs": _flat_specs(args),
        "outputs": _flat_specs(outs),
        "hlo_chars": len(text),
    }
    print(f"  {name}: {len(text)} chars, {len(entry['inputs'])} in / {len(entry['outputs'])} out")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="comma-separated entry filter")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    names = list(ENTRIES) if not args.only else args.only.split(",")
    manifest = {"entries": []}
    # Preserve an existing manifest when exporting a subset.
    mpath = out_dir / "manifest.json"
    if args.only and mpath.exists():
        manifest = json.loads(mpath.read_text())
        manifest["entries"] = [e for e in manifest["entries"] if e["name"] not in names]
    for name in names:
        manifest["entries"].append(export_entry(name, out_dir))
    manifest["entries"].sort(key=lambda e: e["name"])
    mpath.write_text(json.dumps(manifest, indent=2))
    print(f"wrote {mpath} ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    main()
