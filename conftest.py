# Allow `pytest python/tests/` from the repo root: the test suite
# imports the build-time `compile` package that lives under python/.
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent / "python"))

# Offline fallback: the CI image has no `hypothesis` wheel. If the real
# library is importable we never touch sys.path; otherwise expose the
# API-compatible deterministic shim in python/_offline_shims/.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(Path(__file__).parent / "python" / "_offline_shims"))
